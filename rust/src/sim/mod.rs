//! Discrete-event GPU simulator.
//!
//! Substitutes the paper's A100/A30 testbed. Jobs run
//! on MIG instances managed by [`crate::mig::PartitionManager`] and move
//! through explicit phases (alloc → h2d → kernel waves / iterations →
//! d2h → free). The simulator models the contention effects the paper
//! measures:
//!
//! * **PCIe sharing** — the bandwidth-bound fraction of each transfer is
//!   processor-shared among all concurrently-transferring jobs (paper
//!   §5.1, ref [24]); the latency-bound fraction is not.
//! * **Allocator bookkeeping** — cudaMalloc/cudaFree overheads grow with
//!   the number of live MIG instances (paper Table 3). The overhead is
//!   taken from the instance count *when the op starts*, so a job that
//!   spans a fission/fusion pays the cost of the layout it actually
//!   runs under, not the one it was launched under.
//! * **Warp model** — a kernel step on `c` GPCs takes
//!   `ceil(demand/c)` waves (paper §4.3's warp-folding model).
//! * **Power** — pluggable per-instance attribution via the spec's
//!   [`PowerModel`] ([`crate::power::model`]). The default `Legacy`
//!   model is the original linear curve
//!   `P = idle + per_gpc · Σ util_i · gpc_i`, bit for bit; the
//!   `SliceProportional` / `Measured` variants attribute draw to
//!   individual MIG instances ([`GpuSim::instance_power_w`]). Energy is
//!   `∫P dt` at event granularity; with a [`PriceSignal`] attached,
//!   `$ = ∫ price·P dt` accrues alongside ([`GpuSim::cost_usd`]).
//! * **Reconfiguration windows** — executing a
//!   [`PartitionPlan`](crate::mig::PartitionPlan) opens a window whose
//!   duration is the plan's modeled per-op cost
//!   ([`begin_reconfig_window`](GpuSim::begin_reconfig_window)); the
//!   plan's instances are unavailable until the window's
//!   [`SimEvent::ReconfigDone`] fires, and the time is tallied in
//!   [`SimCounters::reconfig_time_s`].
//! * **OOM / observation** — iterative jobs carry an allocator trace;
//!   exceeding the instance's memory raises an OOM event. Per-iteration
//!   allocator [`Observation`]s are *emitted* as
//!   [`SimEvent::MemObserved`] (when the engine is constructed with
//!   `observe: true`) instead of being consumed by an internal monitor:
//!   prediction state lives in the orchestrator-owned
//!   [`BeliefLedger`](crate::estimator::BeliefLedger), which decides
//!   predictive early restarts and executes them via
//!   [`GpuSim::preempt`] — the paper's early restart, with the policy
//!   layer in the loop.
//!
//! # Engine design: indexed event calendar
//!
//! [`GpuSim`] is an *indexed* discrete-event engine: instead of scanning
//! every running job per event (the original scan-and-decrement loop,
//! preserved as the differential-testing oracle in [`naive`]), it keeps
//!
//! * a **real-time calendar** (`BinaryHeap` keyed `(instant, JobId)`):
//!   the absolute completion instant of each job's current non-shared
//!   phase (fixed kernels/iterations, the latency-bound part of a PCIe
//!   transfer, reconfiguration windows). Entries use lazy invalidation:
//!   each carries a token, and entries whose token no longer matches the
//!   job's are discarded on pop, so kills and phase changes are O(1).
//! * a **virtual-service calendar** for processor-shared PCIe
//!   bandwidth, in the style of virtual-time fair queueing: the shared
//!   virtual clock `v_now` advances at `1/n_bw` per simulated second,
//!   a transfer with `s` seconds of bandwidth service completes at
//!   `v_now + s`, and a sharer-count change only changes the *rate* of
//!   `v_now` — no per-transfer rescan or reindex.
//! * **incremental accumulators** maintained at op boundaries only:
//!   `active_sum` (the power model's Σ util·gpc), `mem_sum` (resident
//!   GB of running jobs), and `n_bw` (bandwidth sharers). Energy and
//!   memory integrals are piecewise products `acc · dt` per event, not
//!   per-event reductions, and are reset to exactly zero whenever the
//!   sim drains so float drift cannot leak across batches.
//!
//! Per event the engine does O(log n) heap work plus O(1) accumulator
//! updates, versus the oracle's four O(n) scans and a `Vec` clone.
//! Simultaneous completions are deterministic: co-due entries fire in
//! ascending `JobId` order (the oracle's launch-order rule), and the
//! engine never iterates a hash map to produce a float sum, so results
//! are bit-stable across processes.
//!
//! Job state lives in a [`slab::Slab`] — dense slot storage with a
//! freelist and generation-tagged [`slab::Handle`]s — rather than a
//! `HashMap<JobId, Running>`: every calendar pop resolves its job with
//! one bounds check and one generation compare instead of a hash +
//! probe, which is the difference that shows at fleet-of-fleets scale
//! (millions of events per run; see `benches/des_engine.rs`). Calendar
//! keys carry the handle for O(1) resolution *and* the public `JobId`
//! for the deterministic tie-break; `JobId`s stay monotone and are
//! never reused, so nothing observable depends on slot assignment and
//! snapshot bytes are unchanged by the migration.
//!
//! The oracle ([`naive::NaiveGpuSim`]) implements identical semantics
//! with the original per-event scans; `sim::difftest` proves
//! event-sequence equivalence and makespan/energy agreement within a
//! documented tolerance (1e-6 relative) on random mixes, horizons, and
//! reconfig interleavings.
//!
//! # Checkpointing
//!
//! Both engines serialize their complete mid-run state —
//! partition layout + open reconfiguration window, per-job phase
//! progress (including virtual-service positions of in-flight
//! transfers), calendars, accumulators, counters, records — into a
//! [`GpuSimSnapshot`] / [`naive::NaiveSimSnapshot`] (plain
//! [`Json`](crate::util::Json), no extra dependencies) and rebuild
//! bit-exactly via [`GpuSim::restore`]. Iterative jobs snapshot their
//! [`TraceSpec`](crate::trace::TraceSpec) + seed and regenerate the
//! allocator trace on restore, so snapshots stay small. The
//! correctness bar is `sim::resume_difftest`: run to a random horizon,
//! snapshot, restore into a fresh engine, run to completion, and
//! require event sequence, metrics, and observation stream to be
//! byte-identical to the uninterrupted run — including snapshots taken
//! inside reconfiguration windows and just before OOMs. The layer
//! composes upward into
//! [`OrchestratorCheckpoint`](crate::scheduler::OrchestratorCheckpoint)
//! (warm-started tuning, fault injection).

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

use crate::mig::{GpuSpec, InstanceId, PartitionManager};
use crate::power::{InstanceLoad, PowerBreakdown, PowerModel, PriceSignal};
use crate::predictor::Observation;
use crate::trace::AllocatorTrace;
use crate::workloads::{ComputeModel, JobKind, JobSpec};

pub mod naive;
pub mod slab;

use slab::{Handle, Slab};

#[cfg(test)]
mod difftest;

#[cfg(test)]
mod resume_difftest;

/// Simulator-local job handle.
pub type JobId = usize;

/// Power-model utilization per phase kind.
pub(crate) const UTIL_KERNEL: f64 = 1.0;
pub(crate) const UTIL_XFER: f64 = 0.12;
pub(crate) const UTIL_MISC: f64 = 0.05;
/// Latency-bound transfer inflation per extra live instance (Table 3:
/// myocyte d2h 3.36 s -> 3.47 s across 7 instances).
pub(crate) const XFER_INSTANCE_OVERHEAD: f64 = 0.005;
pub(crate) const EPS: f64 = 1e-9;

/// Which instance-count-dependent overhead an op picks up when it
/// starts (see [`arm_op`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Inflate {
    None,
    /// Multiplicative cudaMalloc bookkeeping (Table 3).
    Alloc,
    /// Additive cudaFree bookkeeping (Table 3).
    Free,
}

/// One atomic unit of job progress. Durations are compiled *base*
/// values; instance-count-dependent overheads are applied by [`arm_op`]
/// when the op starts.
#[derive(Debug, Clone)]
pub(crate) enum Op {
    /// Fixed-duration on-device work. `gpcs_busy` drives the power model.
    Fixed {
        rem: f64,
        util: f64,
        gpcs_busy: f64,
        inflate: Inflate,
    },
    /// PCIe transfer: latency part progresses unconditionally, bandwidth
    /// part is processor-shared.
    Pcie { fixed_rem: f64, bw_rem: f64 },
    /// One iteration of an iterative (trace-carrying) workload; memory
    /// and prediction checks fire on completion.
    IterKernel { rem: f64, iter: usize, gpcs_busy: f64 },
}

/// A job currently occupying an instance (shared by both engines).
#[derive(Debug)]
pub(crate) struct Running {
    pub(crate) spec: JobSpec,
    pub(crate) instance: InstanceId,
    pub(crate) inst_mem_gb: f64,
    /// Compute slices of the instance (constant while allocated).
    pub(crate) inst_slices: u8,
    pub(crate) ops: Vec<Op>,
    /// Index of the op in flight.
    pub(crate) cursor: usize,
    /// Realized allocator trace (iterative jobs only).
    pub(crate) trace: Option<AllocatorTrace>,
    pub(crate) submit_time: f64,
    /// When this (re)launch actually started on the instance.
    pub(crate) start_time: f64,
    /// Memory charged against the utilization integral right now.
    pub(crate) cur_mem_gb: f64,
    /// Indexed engine: token of the job's live calendar entry (older
    /// entries are lazily discarded).
    pub(crate) token: u64,
    /// Indexed engine: the current op is in its PCIe bandwidth-shared
    /// phase (counted in `n_bw`, scheduled on the virtual calendar).
    pub(crate) in_bw: bool,
}

impl Running {
    /// Build the run state for launching `spec` on an instance with
    /// `inst_slices` GPCs. Prediction state lives outside the engines
    /// (the orchestrator's belief ledger); the run state only carries
    /// the realized allocator trace the engine replays.
    pub(crate) fn launch(
        spec: JobSpec,
        instance: InstanceId,
        inst_mem_gb: f64,
        inst_slices: u8,
        now: f64,
        submit_time: f64,
    ) -> Running {
        let ops = compile_ops(&spec, inst_slices);
        let trace = match &spec.compute {
            ComputeModel::Iterative(it) => Some(it.trace.generate(it.trace_seed)),
            _ => None,
        };
        Running {
            spec,
            instance,
            inst_mem_gb,
            inst_slices,
            ops,
            cursor: 0,
            trace,
            submit_time,
            // Clamp: fleet runs deliver arrivals against the
            // least-advanced busy clock, so `now` can trail the
            // submit time by at most an epsilon — a record never
            // shows a job starting before it was submitted.
            start_time: now.max(submit_time),
            cur_mem_gb: 0.0,
            token: 0,
            in_bw: false,
        }
    }
}

/// Compile a job into its op program for an instance with `c` GPCs.
/// Durations are *base* values: the instance-count-dependent Table-3
/// overheads are applied by [`arm_op`] when each op starts.
pub(crate) fn compile_ops(spec: &JobSpec, c: u8) -> Vec<Op> {
    let waves = spec.demand_gpcs.div_ceil(c.max(1)) as f64;
    let gpcs_busy = spec.demand_gpcs.min(c) as f64;
    let misc_busy = c as f64 * UTIL_MISC;

    let pcie = |excl_s: f64, bw_frac: f64| -> Op {
        let bw = excl_s * bw_frac;
        Op::Pcie {
            fixed_rem: excl_s - bw,
            bw_rem: bw,
        }
    };

    let mut ops = Vec::new();
    match &spec.compute {
        ComputeModel::Phases(p) => {
            let bw_frac = bw_fraction(spec);
            ops.push(Op::Fixed {
                rem: p.alloc_s,
                util: UTIL_MISC,
                gpcs_busy: misc_busy,
                inflate: Inflate::Alloc,
            });
            ops.push(pcie(p.h2d_pcie_s, bw_frac));
            for _ in 0..p.steps {
                if p.step_pcie_s > 0.0 {
                    ops.push(pcie(p.step_pcie_s, bw_frac));
                }
                ops.push(Op::Fixed {
                    rem: p.step_s * waves,
                    util: UTIL_KERNEL,
                    gpcs_busy,
                    inflate: Inflate::None,
                });
            }
            ops.push(pcie(p.d2h_pcie_s, bw_frac));
            ops.push(Op::Fixed {
                rem: p.free_s,
                util: UTIL_MISC,
                gpcs_busy: misc_busy,
                inflate: Inflate::Free,
            });
        }
        ComputeModel::Iterative(it) => {
            ops.push(Op::Fixed {
                rem: it.alloc_s,
                util: UTIL_MISC,
                gpcs_busy: misc_busy,
                inflate: Inflate::Alloc,
            });
            ops.push(pcie(it.h2d_pcie_s, 0.8));
            for i in 0..it.trace.n_iters {
                ops.push(Op::IterKernel {
                    rem: it.iter_step_s * waves,
                    iter: i,
                    gpcs_busy,
                });
            }
            ops.push(pcie(it.d2h_pcie_s, 0.2));
            ops.push(Op::Fixed {
                rem: it.free_s,
                util: UTIL_MISC,
                gpcs_busy: misc_busy,
                inflate: Inflate::Free,
            });
        }
    }
    ops
}

/// Apply the instance-count-dependent overheads to an op that is about
/// to start, given the *live* instance count (paper Table 3). Called
/// exactly once per op, at op start — so a job spanning a
/// reconfiguration pays each op under the layout it runs under.
pub(crate) fn arm_op(op: &mut Op, spec: &GpuSpec, n_inst: usize) {
    let n = n_inst.max(1) as f64;
    match op {
        Op::Fixed { rem, inflate, .. } => match inflate {
            Inflate::Alloc => *rem *= 1.0 + spec.alloc_overhead_per_instance * (n - 1.0),
            Inflate::Free => *rem += spec.free_overhead_per_instance_s * (n - 1.0),
            Inflate::None => {}
        },
        Op::Pcie { fixed_rem, .. } => {
            *fixed_rem *= 1.0 + XFER_INSTANCE_OVERHEAD * (n - 1.0);
        }
        Op::IterKernel { .. } => {}
    }
}

/// Power-model contribution of an op on an instance with `inst_slices`
/// GPCs (constant while the op is current).
pub(crate) fn op_active(op: &Op, inst_slices: u8) -> f64 {
    match op {
        Op::Fixed { util, gpcs_busy, .. } => util * gpcs_busy,
        Op::IterKernel { gpcs_busy, .. } => UTIL_KERNEL * gpcs_busy,
        Op::Pcie { .. } => UTIL_XFER * inst_slices as f64,
    }
}

/// Per-job completion record (for turnaround / reporting).
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Workload name from the launched `JobSpec`.
    pub name: String,
    /// When the job entered the system (orchestrator submit or launch).
    pub submit_time: f64,
    /// When the final (successful) launch started; `start_time -
    /// submit_time` is the job's queueing delay.
    pub start_time: f64,
    /// When the job completed; `finish_time - submit_time` is turnaround.
    pub finish_time: f64,
}

/// Counters the metrics layer consumes.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimCounters {
    /// Driver create/destroy operations executed.
    pub reconfig_ops: usize,
    /// Reconfiguration windows opened (plans executed with a window).
    pub reconfig_windows: usize,
    /// Total simulated seconds spent inside reconfiguration windows —
    /// the wall-clock cost of fusion/fission the throughput and energy
    /// tables must account for.
    pub reconfig_time_s: f64,
    /// Jobs killed by out-of-memory and relaunched from scratch.
    pub oom_restarts: usize,
    /// Jobs restarted early on a predicted-OOM signal (prediction runs).
    pub early_restarts: usize,
}

/// Events surfaced to the scheduling policy.
#[derive(Debug)]
pub enum SimEvent {
    /// Job ran to completion; its instance is still allocated (idle).
    Finished {
        /// The finished job's engine-local id.
        job: JobId,
        /// The finished job's spec.
        spec: JobSpec,
        /// The instance it ran on (now idle).
        instance: InstanceId,
        /// The job's original submission time.
        submit_time: f64,
    },
    /// Iterative job exceeded its instance memory at `iter`.
    Oom {
        /// The killed job's engine-local id.
        job: JobId,
        /// The killed job's spec (for relaunch).
        spec: JobSpec,
        /// The instance it overflowed (now idle).
        instance: InstanceId,
        /// The job's original submission time (turnaround anchor).
        submit_time: f64,
        /// Iteration at which memory overflowed.
        iter: usize,
        /// Footprint at the overflow, GB.
        mem_gb: f64,
    },
    /// Predictor converged above the instance size; job preempted early
    /// (raised by [`GpuSim::preempt`] on the caller's decision — the
    /// engine itself never predicts).
    Preempted {
        /// The preempted job's engine-local id.
        job: JobId,
        /// The preempted job's spec (for relaunch on a bigger slice).
        spec: JobSpec,
        /// The instance it vacated (now idle).
        instance: InstanceId,
        /// The job's original submission time (turnaround anchor).
        submit_time: f64,
        /// Iteration at which the preemption landed.
        iter: usize,
        /// The converged peak projection that triggered the preempt, GB.
        predicted_peak_gb: f64,
    },
    /// One iteration's allocator observation from a running iterative
    /// job (emitted only when the engine was built with `observe:
    /// true`). The job keeps running; the consumer (the orchestrator's
    /// belief ledger) may answer with [`GpuSim::preempt`] at the same
    /// instant. `mem_gb` is the iteration's physical footprint.
    MemObserved {
        /// The observed (still-running) job's engine-local id.
        job: JobId,
        /// The instance it occupies.
        instance: InstanceId,
        /// Iteration index of the observation.
        iter: usize,
        /// The allocator counters fed to the predictor.
        obs: Observation,
        /// The iteration's physical footprint, GB.
        mem_gb: f64,
    },
    /// A reconfiguration window completed.
    ReconfigDone,
}

pub(crate) enum KillKind {
    Oom { iter: usize, mem_gb: f64 },
    Preempt { iter: usize, peak: f64 },
}

/// Bandwidth-bound fraction of a workload's transfers. Transfer-heavy
/// benchmarks (NW, streamcluster, sort...) contend for PCIe; small
/// latency-bound movers (myocyte) barely do (Table 3 vs Table 4).
pub(crate) fn bw_fraction(spec: &JobSpec) -> f64 {
    match spec.kind {
        JobKind::Dnn => 0.85,
        JobKind::Llm => 0.8,
        JobKind::Rodinia => match spec.name.as_str() {
            "myocyte" => 0.02,
            "nw" | "b+tree" | "streamcluster" | "kmeans" | "dwt2d" => 0.5,
            "hybridsort" | "mummergpu" => 0.6,
            "particlefilter" | "nn" => 0.3,
            _ => 0.15,
        },
    }
}

// ------------------------------------------------- checkpoint codecs
//
// Bit-exact JSON snapshot forms for the run state shared by both
// engines. Floats go through `util::snap` (text round-trips preserve
// every bit, including -0.0 and specials); realized allocator traces
// are never serialized — an iterative job's `Running` carries its
// `TraceSpec` + seed inside the `JobSpec`, and restore regenerates the
// identical trace exactly like [`Running::launch`] does.

pub(crate) fn op_to_json(op: &Op) -> crate::util::Json {
    use crate::util::snap::f64_to_json;
    use crate::util::Json;
    match op {
        Op::Fixed {
            rem,
            util,
            gpcs_busy,
            inflate,
        } => Json::obj(vec![
            ("k", Json::str("fixed")),
            ("rem", f64_to_json(*rem)),
            ("util", f64_to_json(*util)),
            ("gpcs_busy", f64_to_json(*gpcs_busy)),
            (
                "inflate",
                Json::str(match inflate {
                    Inflate::None => "none",
                    Inflate::Alloc => "alloc",
                    Inflate::Free => "free",
                }),
            ),
        ]),
        Op::Pcie { fixed_rem, bw_rem } => Json::obj(vec![
            ("k", Json::str("pcie")),
            ("fixed_rem", f64_to_json(*fixed_rem)),
            ("bw_rem", f64_to_json(*bw_rem)),
        ]),
        Op::IterKernel {
            rem,
            iter,
            gpcs_busy,
        } => Json::obj(vec![
            ("k", Json::str("iter")),
            ("rem", f64_to_json(*rem)),
            ("iter", Json::num(*iter as f64)),
            ("gpcs_busy", f64_to_json(*gpcs_busy)),
        ]),
    }
}

pub(crate) fn op_from_json(j: &crate::util::Json) -> anyhow::Result<Op> {
    use crate::util::snap::{f64_from_json, usize_from_json};
    match j.get("k").as_str() {
        Some("fixed") => Ok(Op::Fixed {
            rem: f64_from_json(j.get("rem"))?,
            util: f64_from_json(j.get("util"))?,
            gpcs_busy: f64_from_json(j.get("gpcs_busy"))?,
            inflate: match j.get("inflate").as_str() {
                Some("none") => Inflate::None,
                Some("alloc") => Inflate::Alloc,
                Some("free") => Inflate::Free,
                other => anyhow::bail!("unknown inflate tag {other:?}"),
            },
        }),
        Some("pcie") => Ok(Op::Pcie {
            fixed_rem: f64_from_json(j.get("fixed_rem"))?,
            bw_rem: f64_from_json(j.get("bw_rem"))?,
        }),
        Some("iter") => Ok(Op::IterKernel {
            rem: f64_from_json(j.get("rem"))?,
            iter: usize_from_json(j.get("iter"))?,
            gpcs_busy: f64_from_json(j.get("gpcs_busy"))?,
        }),
        other => anyhow::bail!("unknown op tag {other:?}"),
    }
}

pub(crate) fn running_to_json(r: &Running) -> crate::util::Json {
    use crate::util::snap::{f64_to_json, u64_to_json};
    use crate::util::Json;
    Json::obj(vec![
        ("spec", r.spec.to_snap_json()),
        ("instance", Json::num(r.instance as f64)),
        ("inst_mem_gb", f64_to_json(r.inst_mem_gb)),
        ("inst_slices", Json::num(r.inst_slices as f64)),
        ("ops", Json::Arr(r.ops.iter().map(op_to_json).collect())),
        ("cursor", Json::num(r.cursor as f64)),
        ("submit_time", f64_to_json(r.submit_time)),
        ("start_time", f64_to_json(r.start_time)),
        ("cur_mem_gb", f64_to_json(r.cur_mem_gb)),
        ("token", u64_to_json(r.token)),
        ("in_bw", Json::Bool(r.in_bw)),
    ])
}

pub(crate) fn running_from_json(j: &crate::util::Json) -> anyhow::Result<Running> {
    use crate::util::snap::{f64_from_json, u64_from_json, usize_from_json};
    let spec = JobSpec::from_snap_json(j.get("spec"))?;
    // Regenerate the realized trace exactly like `Running::launch`:
    // deterministic per (TraceSpec, seed), so the restored engine
    // replays bit-identical iterations.
    let trace = match &spec.compute {
        ComputeModel::Iterative(it) => Some(it.trace.generate(it.trace_seed)),
        _ => None,
    };
    let ops = j
        .get("ops")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected op array"))?
        .iter()
        .map(op_from_json)
        .collect::<anyhow::Result<Vec<Op>>>()?;
    let instance = usize_from_json(j.get("instance"))?;
    anyhow::ensure!(instance <= InstanceId::MAX as usize);
    let inst_slices = usize_from_json(j.get("inst_slices"))?;
    anyhow::ensure!(inst_slices <= u8::MAX as usize);
    Ok(Running {
        spec,
        instance: instance as InstanceId,
        inst_mem_gb: f64_from_json(j.get("inst_mem_gb"))?,
        inst_slices: inst_slices as u8,
        ops,
        cursor: usize_from_json(j.get("cursor"))?,
        trace,
        submit_time: f64_from_json(j.get("submit_time"))?,
        start_time: f64_from_json(j.get("start_time"))?,
        cur_mem_gb: f64_from_json(j.get("cur_mem_gb"))?,
        token: u64_from_json(j.get("token"))?,
        in_bw: j.get("in_bw").as_bool().unwrap_or(false),
    })
}

pub(crate) fn record_to_json(r: &JobRecord) -> crate::util::Json {
    use crate::util::snap::f64_to_json;
    use crate::util::Json;
    Json::obj(vec![
        ("name", Json::str(&r.name)),
        ("submit_time", f64_to_json(r.submit_time)),
        ("start_time", f64_to_json(r.start_time)),
        ("finish_time", f64_to_json(r.finish_time)),
    ])
}

pub(crate) fn record_from_json(j: &crate::util::Json) -> anyhow::Result<JobRecord> {
    use crate::util::snap::f64_from_json;
    Ok(JobRecord {
        name: j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("record missing name"))?
            .to_string(),
        submit_time: f64_from_json(j.get("submit_time"))?,
        start_time: f64_from_json(j.get("start_time"))?,
        finish_time: f64_from_json(j.get("finish_time"))?,
    })
}

pub(crate) fn records_to_json(rs: &[JobRecord]) -> crate::util::Json {
    crate::util::Json::Arr(rs.iter().map(record_to_json).collect())
}

pub(crate) fn records_from_json(j: &crate::util::Json) -> anyhow::Result<Vec<JobRecord>> {
    j.as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected record array"))?
        .iter()
        .map(record_from_json)
        .collect()
}

pub(crate) fn counters_to_json(c: &SimCounters) -> crate::util::Json {
    use crate::util::snap::f64_to_json;
    use crate::util::Json;
    Json::obj(vec![
        ("reconfig_ops", Json::num(c.reconfig_ops as f64)),
        ("reconfig_windows", Json::num(c.reconfig_windows as f64)),
        ("reconfig_time_s", f64_to_json(c.reconfig_time_s)),
        ("oom_restarts", Json::num(c.oom_restarts as f64)),
        ("early_restarts", Json::num(c.early_restarts as f64)),
    ])
}

pub(crate) fn counters_from_json(j: &crate::util::Json) -> anyhow::Result<SimCounters> {
    use crate::util::snap::{f64_from_json, usize_from_json};
    Ok(SimCounters {
        reconfig_ops: usize_from_json(j.get("reconfig_ops"))?,
        reconfig_windows: usize_from_json(j.get("reconfig_windows"))?,
        reconfig_time_s: f64_from_json(j.get("reconfig_time_s"))?,
        oom_restarts: usize_from_json(j.get("oom_restarts"))?,
        early_restarts: usize_from_json(j.get("early_restarts"))?,
    })
}

/// Calendar entry: an absolute due instant (real seconds on the
/// real-time calendar, virtual service on the virtual one) with a
/// deterministic `(instant, JobId)` total order. `token` invalidates
/// stale entries lazily; `h` is the job's slab handle — resolution
/// only, excluded from the order (and from snapshots: slot assignment
/// is not deterministic, `JobId` is).
#[derive(Debug, Clone, Copy)]
struct CalKey {
    t: f64,
    job: JobId,
    token: u64,
    h: Handle,
}

impl PartialEq for CalKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for CalKey {}

impl PartialOrd for CalKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for CalKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.job.cmp(&other.job))
            .then(self.token.cmp(&other.token))
    }
}

/// Serde-free JSON snapshot of a [`GpuSim`], produced by
/// [`GpuSim::snapshot`]. One per GPU inside an
/// `OrchestratorCheckpoint`.
#[derive(Debug, Clone)]
pub struct GpuSimSnapshot(pub crate::util::Json);

/// Serialize a calendar's **live** entries (token matches the owning
/// job's) in ascending key order: `[[t, job, token], ...]`. Stale
/// lazily-invalidated entries are dropped — they are semantically
/// absent, and filtering makes snapshot bytes independent of discard
/// timing.
fn cal_to_json(
    heap: &BinaryHeap<Reverse<CalKey>>,
    running: &Slab<(JobId, Running)>,
) -> crate::util::Json {
    use crate::util::snap::{f64_to_json, u64_to_json};
    use crate::util::Json;
    let mut live: Vec<CalKey> = heap
        .iter()
        .map(|Reverse(k)| *k)
        .filter(|k| running.get(k.h).is_some_and(|(_, r)| r.token == k.token))
        .collect();
    live.sort();
    Json::Arr(
        live.into_iter()
            .map(|k| {
                Json::Arr(vec![
                    f64_to_json(k.t),
                    Json::num(k.job as f64),
                    u64_to_json(k.token),
                ])
            })
            .collect(),
    )
}

/// Inverse of [`cal_to_json`]. Handles are not serialized (slot
/// assignment is run-local); `handles` maps each restored job back to
/// its fresh slab slot, and every live calendar entry must resolve.
fn cal_from_json(
    j: &crate::util::Json,
    handles: &HashMap<JobId, Handle>,
) -> anyhow::Result<BinaryHeap<Reverse<CalKey>>> {
    use crate::util::snap::{f64_from_json, u64_from_json, usize_from_json};
    let mut heap = BinaryHeap::new();
    for row in j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("expected calendar array"))?
    {
        let job: JobId = usize_from_json(row.at(1))?;
        let h = *handles
            .get(&job)
            .ok_or_else(|| anyhow::anyhow!("calendar entry for unknown job {job}"))?;
        heap.push(Reverse(CalKey {
            t: f64_from_json(row.at(0))?,
            job,
            token: u64_from_json(row.at(2))?,
            h,
        }));
    }
    Ok(heap)
}

/// Pop stale entries off the top of a calendar; return the first live
/// key without removing it.
fn peek_valid(
    heap: &mut BinaryHeap<Reverse<CalKey>>,
    running: &Slab<(JobId, Running)>,
) -> Option<CalKey> {
    while let Some(Reverse(k)) = heap.peek() {
        // Generation tag catches freed-and-reused slots; the token
        // catches a live job's superseded entries.
        let live = running.get(k.h).is_some_and(|(_, r)| r.token == k.token);
        if live {
            return Some(*k);
        }
        heap.pop();
    }
    None
}

/// The simulated GPU (indexed event-calendar engine; see module docs).
pub struct GpuSim {
    /// The simulated GPU's geometry/power model.
    pub spec: Arc<GpuSpec>,
    /// MIG partition state (allocate/free/reconfigure instances here).
    pub mgr: PartitionManager,
    now: f64,
    /// Job storage: dense slots, freelist reuse, generation-tagged
    /// handles. The public `JobId` rides alongside each entry; slot
    /// assignment itself is unobservable (see the module docs).
    running: Slab<(JobId, Running)>,
    /// Occupancy index: instance -> job handle (O(1) `running_on`).
    by_instance: HashMap<InstanceId, Handle>,
    /// Real-time calendar: non-shared phase completions.
    cal: BinaryHeap<Reverse<CalKey>>,
    /// Virtual-service calendar: processor-shared PCIe bw completions.
    vcal: BinaryHeap<Reverse<CalKey>>,
    /// Accumulated per-sharer virtual service (advances at `1/n_bw`).
    v_now: f64,
    /// Jobs currently in a bandwidth-shared transfer phase.
    n_bw: usize,
    /// Power model Σ util·gpc of current ops (op-boundary maintained).
    active_sum: f64,
    /// Σ resident memory of running jobs (op-boundary maintained).
    mem_sum: f64,
    token_counter: u64,
    /// Reusable scratch for the co-due set (avoids a per-event malloc).
    due_scratch: Vec<(CalKey, bool)>,
    /// Absolute completion instant of the open reconfiguration window.
    reconfig_due: Option<f64>,
    next_id: JobId,
    energy_j: f64,
    mem_gb_integral: f64,
    /// Electricity cost integral, $ = ∫ price·power dt. Stays exactly
    /// 0.0 (and adds no work) unless a price signal is attached.
    cost_usd: f64,
    /// Optional $/kWh signal (structural, like the spec: re-attached by
    /// the harness after a checkpoint restore, never serialized).
    price: Option<PriceSignal>,
    /// Reconfiguration/restart counters the metrics layer consumes.
    pub counters: SimCounters,
    /// Completion records of every finished job.
    pub records: Vec<JobRecord>,
    /// Emit [`SimEvent::MemObserved`] per iteration of iterative jobs.
    /// Off by default-equivalent callers (no-prediction runs) so their
    /// event streams are unchanged; the orchestrator enables it when
    /// its belief ledger runs prediction.
    observe: bool,
}

impl GpuSim {
    /// `observe` controls per-iteration [`SimEvent::MemObserved`]
    /// emission (historically this flag enabled the in-sim predictor;
    /// the prediction state now lives behind the caller's belief
    /// ledger).
    pub fn new(spec: Arc<GpuSpec>, observe: bool) -> Self {
        let mgr = PartitionManager::new(spec.clone());
        GpuSim {
            spec,
            mgr,
            now: 0.0,
            running: Slab::new(),
            by_instance: HashMap::new(),
            cal: BinaryHeap::new(),
            vcal: BinaryHeap::new(),
            v_now: 0.0,
            n_bw: 0,
            active_sum: 0.0,
            mem_sum: 0.0,
            token_counter: 0,
            due_scratch: Vec::new(),
            reconfig_due: None,
            next_id: 0,
            energy_j: 0.0,
            mem_gb_integral: 0.0,
            cost_usd: 0.0,
            price: None,
            counters: SimCounters::default(),
            records: Vec::new(),
            observe,
        }
    }

    /// Reuse a prebuilt reachability table (avoids re-precomputing in
    /// benches that build many sims).
    pub fn with_manager(spec: Arc<GpuSpec>, mgr: PartitionManager, observe: bool) -> Self {
        let mut s = Self::new(spec, observe);
        s.mgr = mgr;
        s
    }

    /// Current simulated time, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Energy integrated by the power model so far, joules.
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    /// Time-integral of resident job memory (GB·s), for utilization.
    pub fn mem_gb_integral(&self) -> f64 {
        self.mem_gb_integral
    }

    /// Number of jobs currently running.
    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    /// True if a job occupies `instance`.
    pub fn running_on(&self, instance: InstanceId) -> bool {
        self.by_instance.contains_key(&instance)
    }

    /// True while a reconfiguration window is open.
    pub fn is_reconfiguring(&self) -> bool {
        self.reconfig_due.is_some()
    }

    /// Launch `spec` on an already-allocated instance. `submit_time` is
    /// the job's original batch submit time (turnaround anchor).
    pub fn launch(&mut self, spec: JobSpec, instance: InstanceId, submit_time: f64) -> JobId {
        assert!(
            !self.running_on(instance),
            "instance {instance} already busy"
        );
        let c = self
            .mgr
            .compute_slices_of(instance)
            .expect("launch on unknown instance");
        let inst_mem = self.mgr.mem_gb_of(instance).unwrap();
        let n_inst = self.mgr.instance_count();
        let mut r = Running::launch(spec, instance, inst_mem, c, self.now, submit_time);
        if let Some(op) = r.ops.first_mut() {
            arm_op(op, &self.spec, n_inst);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.active_sum += r.ops.first().map(|o| op_active(o, c)).unwrap_or(0.0);
        let h = self.running.insert((id, r));
        self.by_instance.insert(instance, h);
        self.schedule_current(id, h);
        id
    }

    /// Begin a reconfiguration window of `ops` create/destroy operations
    /// at the uniform legacy cost (`ops * reconfig_op_s`). Retained for
    /// the legacy golden loops and uniform-cost callers; plan-driven
    /// callers charge the modeled cost via
    /// [`begin_reconfig_window`](Self::begin_reconfig_window).
    pub fn begin_reconfig(&mut self, ops: usize) {
        // Accumulate exactly like `PartitionManager::plan_cost_s` (one
        // add per op) so the uniform path and the plan-priced path stay
        // bit-for-bit identical — the parity tests compare makespans
        // exactly.
        let duration: f64 = (0..ops).fold(0.0, |acc, _| acc + self.spec.reconfig_op_s);
        self.begin_reconfig_window(duration, ops);
    }

    /// Begin a reconfiguration window of `duration_s` simulated seconds
    /// covering `n_ops` driver operations (a `PartitionPlan`'s modeled
    /// cost). While the window is open no further reconfiguration may
    /// start; the orchestrator commits the plan's creates only when the
    /// window's [`SimEvent::ReconfigDone`] fires, so the affected
    /// instances are unavailable for the whole window. A call with zero
    /// ops and zero duration is a no-op (no window, no event).
    pub fn begin_reconfig_window(&mut self, duration_s: f64, n_ops: usize) {
        assert!(self.reconfig_due.is_none(), "reconfig already in flight");
        if n_ops == 0 && duration_s <= 0.0 {
            return;
        }
        let duration_s = duration_s.max(0.0);
        self.counters.reconfig_ops += n_ops;
        self.counters.reconfig_windows += 1;
        self.counters.reconfig_time_s += duration_s;
        self.reconfig_due = Some(self.now + duration_s);
    }

    /// Instantaneous power draw (W). Under [`PowerModel::Legacy`] (the
    /// default) this is the incrementally-maintained linear curve,
    /// expression-for-expression the original code — runs are
    /// byte-identical. The per-instance variants rebuild the load list
    /// from the live partition (id order, so summation is bit-stable).
    fn power_w(&self) -> f64 {
        match &self.spec.power {
            PowerModel::Legacy => {
                let per_gpc = (self.spec.max_power_w - self.spec.idle_power_w)
                    / self.spec.total_compute as f64;
                self.spec.idle_power_w + per_gpc * self.active_sum.max(0.0)
            }
            model => model.total_w(&self.spec, &self.instance_loads()),
        }
    }

    /// Per-instance activity of the live partition, in `InstanceId`
    /// order (the power models' input; idle instances carry 0).
    fn instance_loads(&self) -> Vec<InstanceLoad> {
        self.mgr
            .live_instances()
            .into_iter()
            .map(|(id, profile)| {
                let active = self
                    .by_instance
                    .get(&id)
                    .and_then(|&h| self.running.get(h))
                    .and_then(|(_, r)| r.ops.get(r.cursor).map(|o| op_active(o, r.inst_slices)))
                    .unwrap_or(0.0);
                InstanceLoad {
                    id,
                    profile,
                    active,
                }
            })
            .collect()
    }

    /// Worst-case per-instance activity: every busy instance charged
    /// `min(demand_gpcs, inst_slices)` — an upper bound on
    /// [`op_active`] across every op kind — idle instances 0. The
    /// candidate launch (if any) saturates its target instance the same
    /// way.
    fn reservation_loads(&self, candidate: Option<(InstanceId, u8)>) -> Vec<InstanceLoad> {
        self.mgr
            .live_instances()
            .into_iter()
            .map(|(id, profile)| {
                let slices = self.spec.profiles[profile].compute_slices;
                let mut active = self
                    .by_instance
                    .get(&id)
                    .and_then(|&h| self.running.get(h))
                    .map(|(_, r)| r.spec.demand_gpcs.min(r.inst_slices) as f64)
                    .unwrap_or(0.0);
                if let Some((cand, demand)) = candidate {
                    if cand == id {
                        active = demand.min(slices) as f64;
                    }
                }
                InstanceLoad {
                    id,
                    profile,
                    active,
                }
            })
            .collect()
    }

    /// Instantaneous draw right now, W (the integrand of
    /// [`energy_j`](Self::energy_j)).
    pub fn current_power_w(&self) -> f64 {
        self.power_w()
    }

    /// Per-instance draw attribution right now (chassis floor +
    /// per-instance watts, id order). Available under every
    /// [`PowerModel`] variant.
    pub fn power_breakdown(&self) -> PowerBreakdown {
        self.spec.power.breakdown(&self.spec, &self.instance_loads())
    }

    /// The draw attributed to one live instance right now, W (`None`
    /// if the instance does not exist).
    pub fn instance_power_w(&self, id: InstanceId) -> Option<f64> {
        self.power_breakdown().instance_w(id)
    }

    /// Worst-case draw of the current workload, W: every busy instance
    /// saturated to its job's demand. Actual draw never exceeds it
    /// (monotonicity of every model variant), and it only changes at
    /// launch/finish/reconfig events — the power-cap governor's
    /// admission currency.
    pub fn power_reservation_w(&self) -> f64 {
        self.spec
            .power
            .total_w(&self.spec, &self.reservation_loads(None))
    }

    /// [`power_reservation_w`](Self::power_reservation_w) as it would
    /// read after launching a `demand_gpcs` job on `instance`.
    pub fn power_projection_w(&self, instance: InstanceId, demand_gpcs: u8) -> f64 {
        self.spec
            .power
            .total_w(&self.spec, &self.reservation_loads(Some((instance, demand_gpcs))))
    }

    /// Attach (or clear) the electricity price signal. The cost
    /// integral accrues from the current instant; an unpriced sim does
    /// no cost work at all.
    pub fn set_price_signal(&mut self, sig: Option<PriceSignal>) {
        self.price = sig;
    }

    /// The attached price signal, if any.
    pub fn price_signal(&self) -> Option<&PriceSignal> {
        self.price.as_ref()
    }

    /// Electricity cost integrated so far, $ (0.0 with no signal).
    pub fn cost_usd(&self) -> f64 {
        self.cost_usd
    }

    /// Resolve a public `JobId` to its live slab handle. Linear scan:
    /// the live set is bounded by the instance count, and this runs
    /// only on external entry points (`preempt`), never per event.
    fn handle_of(&self, id: JobId) -> Option<Handle> {
        self.running
            .iter()
            .find(|(_, (j, _))| *j == id)
            .map(|(h, _)| h)
    }

    /// (Re)schedule job `id`'s current phase on the appropriate
    /// calendar, invalidating any previous entry via a fresh token.
    fn schedule_current(&mut self, id: JobId, h: Handle) {
        self.token_counter += 1;
        let token = self.token_counter;
        let now = self.now;
        let v_now = self.v_now;
        let (_, r) = self.running.get_mut(h).unwrap();
        r.token = token;
        r.in_bw = false;
        let (t, shared) = match r.ops.get(r.cursor) {
            // Exhausted program: due immediately, so a release build
            // finishes the job instead of deriving an infinite dt (the
            // NaN-energy bug class; see the regression test).
            None => (now, false),
            Some(Op::Fixed { rem, .. }) | Some(Op::IterKernel { rem, .. }) => {
                (now + rem.max(0.0), false)
            }
            Some(Op::Pcie { fixed_rem, bw_rem }) => {
                if *fixed_rem > EPS {
                    (now + *fixed_rem, false)
                } else if *bw_rem > EPS {
                    r.in_bw = true;
                    (v_now + *bw_rem, true)
                } else {
                    (now, false)
                }
            }
        };
        let key = CalKey {
            t,
            job: id,
            token,
            h,
        };
        if shared {
            self.n_bw += 1;
            self.vcal.push(Reverse(key));
        } else {
            self.cal.push(Reverse(key));
        }
    }

    /// Advance simulated time until the next scheduler-visible event.
    /// Returns `None` when nothing is running and no reconfig is pending.
    pub fn advance(&mut self) -> Option<SimEvent> {
        self.advance_with_horizon(None)
    }

    /// Like [`advance`](Self::advance), but never moves the clock past
    /// `horizon` (used by the orchestrator so online job arrivals can
    /// interleave with in-flight work). Returns `None` either when the
    /// sim is drained or when the horizon is reached without a
    /// scheduler-visible event; the caller distinguishes the two by
    /// checking [`now`](Self::now) against the horizon.
    pub fn advance_with_horizon(&mut self, horizon: Option<f64>) -> Option<SimEvent> {
        loop {
            if self.running.is_empty() && self.reconfig_due.is_none() {
                return None;
            }
            // 1. earliest pending instant across both calendars and the
            // reconfiguration window
            let t_cal = peek_valid(&mut self.cal, &self.running).map(|k| k.t);
            let rate = self.n_bw.max(1) as f64;
            let t_vcal = peek_valid(&mut self.vcal, &self.running)
                .map(|k| self.now + (k.t - self.v_now).max(0.0) * rate);
            let mut due = f64::INFINITY;
            for t in [t_cal, t_vcal, self.reconfig_due].into_iter().flatten() {
                if t < due {
                    due = t;
                }
            }
            // Every running job keeps a live calendar entry (even an
            // exhausted program is scheduled as due-now), so `due` is
            // finite whenever anything is pending; the guard keeps a
            // release build NaN-free even if that invariant breaks.
            debug_assert!(due.is_finite(), "indexed calendar lost an event");
            let due = if due.is_finite() { due } else { self.now };
            let mut target = due.max(self.now);
            // Clip to the horizon: no transition completes before it, so
            // after integrating up to the horizon we hand control back.
            let mut clipped = false;
            if let Some(h) = horizon {
                let lim = h.max(self.now);
                if lim + EPS < target {
                    target = lim;
                    clipped = true;
                }
            }

            // 2. integrate power + memory over [now, target)
            let dt = target - self.now;
            if dt > 0.0 {
                let p = self.power_w();
                self.energy_j += p * dt;
                if let Some(sig) = &self.price {
                    self.cost_usd += sig.cost_usd(p, self.now, target);
                }
                self.mem_gb_integral += self.mem_sum.max(0.0) * dt;
                if self.n_bw > 0 {
                    self.v_now += dt / self.n_bw as f64;
                }
                self.now = target;
            }
            if clipped {
                return None;
            }

            // 3. fire: reconfiguration first on ties (the oracle checks
            // the window before job transitions)
            if let Some(rc) = self.reconfig_due {
                if rc <= self.now + EPS {
                    self.reconfig_due = None;
                    return Some(SimEvent::ReconfigDone);
                }
            }
            // 4. fire one due job transition (smallest JobId among the
            // co-due set — the oracle's launch-order rule)
            if let Some((id, h)) = self.pop_due_job() {
                if let Some(ev) = self.fire(id, h) {
                    return Some(ev);
                }
            }
        }
    }

    /// Pop every calendar entry due at this instant (within `EPS`) and
    /// return the smallest `JobId`, pushing the rest back. Uses the
    /// reusable scratch buffer: this runs once per event, and the
    /// common case is a single due entry.
    fn pop_due_job(&mut self) -> Option<(JobId, Handle)> {
        let mut due = std::mem::take(&mut self.due_scratch);
        due.clear();
        while let Some(k) = peek_valid(&mut self.cal, &self.running) {
            if k.t <= self.now + EPS {
                self.cal.pop();
                due.push((k, false));
            } else {
                break;
            }
        }
        while let Some(k) = peek_valid(&mut self.vcal, &self.running) {
            // Due test in *virtual* seconds, exactly like the oracle's
            // `bw_rem <= EPS` check (which absorbs up to n_bw·EPS real
            // seconds) — a real-seconds threshold here would group
            // co-due shared completions differently than the oracle.
            if k.t - self.v_now <= EPS {
                self.vcal.pop();
                due.push((k, true));
            } else {
                break;
            }
        }
        let best = due
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.0.job)
            .map(|(i, _)| i);
        let job = best.map(|i| {
            let (key, _) = due.swap_remove(i);
            for &(other, shared) in &due {
                if shared {
                    self.vcal.push(Reverse(other));
                } else {
                    self.cal.push(Reverse(other));
                }
            }
            (key.job, key.h)
        });
        self.due_scratch = due;
        job
    }

    /// Handle the firing of job `id`'s calendar entry: finish the
    /// current phase, either transitioning within the op (PCIe
    /// latency → bandwidth) or completing it.
    fn fire(&mut self, id: JobId, h: Handle) -> Option<SimEvent> {
        let (_, r) = self.running.get_mut(h).expect("fired a stale entry");
        match r.ops.get_mut(r.cursor) {
            Some(Op::Fixed { rem, .. }) | Some(Op::IterKernel { rem, .. }) => *rem = 0.0,
            Some(Op::Pcie { fixed_rem, bw_rem }) => {
                if r.in_bw {
                    *bw_rem = 0.0;
                    r.in_bw = false;
                    self.n_bw -= 1;
                } else {
                    *fixed_rem = 0.0;
                    if *bw_rem > EPS {
                        // Latency part done: join the processor-shared
                        // pool (internal, not scheduler-visible).
                        self.schedule_current(id, h);
                        return None;
                    }
                    *bw_rem = 0.0;
                }
            }
            None => {}
        }
        self.complete_op(id, h)
    }

    /// Fast-forward an idle GPU to `t` (online mode: nothing to do until
    /// the next arrival). Only the idle power floor accrues.
    pub fn idle_until(&mut self, t: f64) {
        // Hard error (not a debug_assert): skipping time over running
        // jobs would silently drop their energy/progress in release
        // builds.
        assert!(
            self.running.is_empty() && self.reconfig_due.is_none(),
            "idle_until on a busy sim"
        );
        if t > self.now {
            // Legacy keeps the original expression (idle floor only);
            // the per-instance models charge the allocated-but-idle
            // floors of the current partition layout.
            let p = match &self.spec.power {
                PowerModel::Legacy => self.spec.idle_power_w,
                model => model.total_w(&self.spec, &self.instance_loads()),
            };
            self.energy_j += p * (t - self.now);
            if let Some(sig) = &self.price {
                self.cost_usd += sig.cost_usd(p, self.now, t);
            }
            self.now = t;
        }
    }

    /// Update a job's resident memory, keeping the accumulator in sync.
    fn set_mem(&mut self, h: Handle, mem_gb: f64) {
        let (_, r) = self.running.get_mut(h).unwrap();
        self.mem_sum += mem_gb - r.cur_mem_gb;
        r.cur_mem_gb = mem_gb;
    }

    /// Remove a job, unwinding every accumulator it contributes to.
    /// The slab bumps the slot's generation, so every calendar entry
    /// still pointing at it goes stale without a sweep.
    fn remove(&mut self, h: Handle) -> Running {
        let (_, r) = self.running.remove(h).unwrap();
        self.by_instance.remove(&r.instance);
        self.mem_sum -= r.cur_mem_gb;
        self.active_sum -= r
            .ops
            .get(r.cursor)
            .map(|o| op_active(o, r.inst_slices))
            .unwrap_or(0.0);
        if r.in_bw {
            self.n_bw -= 1;
        }
        if self.running.is_empty() {
            // Squash float drift so it cannot leak across batches.
            debug_assert!(self.n_bw == 0);
            self.active_sum = 0.0;
            self.mem_sum = 0.0;
            self.n_bw = 0;
        }
        r
    }

    /// Handle completion of job `id`'s current op; may emit an event.
    fn complete_op(&mut self, id: JobId, h: Handle) -> Option<SimEvent> {
        // Allocator observation to emit after the job's next op is
        // armed (the job keeps running; the belief ledger decides).
        let mut observed: Option<(usize, Observation, f64)> = None;
        let (_, r) = self.running.get_mut(h).unwrap();
        let instance = r.instance;
        match r.ops.get(r.cursor) {
            Some(Op::Fixed { .. }) | Some(Op::Pcie { .. }) => {
                // Memory becomes resident once the alloc (cursor 0) ends.
                if r.cursor == 0 {
                    if let ComputeModel::Phases(_) = r.spec.compute {
                        let mem = r.spec.true_mem_gb;
                        let over = mem > r.inst_mem_gb + EPS;
                        self.set_mem(h, mem);
                        // Mis-estimated static job: OOM as soon as the
                        // allocation exceeds the slice.
                        if over {
                            self.counters.oom_restarts += 1;
                            return Some(self.kill(id, h, KillKind::Oom { iter: 0, mem_gb: mem }));
                        }
                    }
                }
            }
            Some(Op::IterKernel { iter, .. }) => {
                let iter = *iter;
                let trace = r.trace.as_ref().expect("iterative job has a trace");
                let mem = trace.phys_gb[iter];
                let obs = trace.observation(iter);
                let inst_mem = r.inst_mem_gb;
                let oom = mem > inst_mem + EPS;
                self.set_mem(h, mem.min(inst_mem));
                if oom {
                    self.counters.oom_restarts += 1;
                    return Some(self.kill(id, h, KillKind::Oom { iter, mem_gb: mem }));
                }
                if self.observe {
                    observed = Some((iter, obs, mem));
                }
            }
            None => {}
        }
        // Advance the cursor; finish the job if the program is done,
        // otherwise arm the next op under the *live* instance layout
        // (Table-3 overheads are taken at op start, not at launch).
        let n_inst = self.mgr.instance_count();
        let (_, r) = self.running.get_mut(h).unwrap();
        let old_active = r
            .ops
            .get(r.cursor)
            .map(|o| op_active(o, r.inst_slices))
            .unwrap_or(0.0);
        self.active_sum -= old_active;
        if r.cursor < r.ops.len() {
            r.cursor += 1;
        }
        if r.cursor >= r.ops.len() {
            let r = self.remove(h);
            self.records.push(JobRecord {
                name: r.spec.name.clone(),
                submit_time: r.submit_time,
                start_time: r.start_time,
                finish_time: self.now,
            });
            return Some(SimEvent::Finished {
                job: id,
                spec: r.spec,
                instance: r.instance,
                submit_time: r.submit_time,
            });
        }
        arm_op(&mut r.ops[r.cursor], &self.spec, n_inst);
        let new_active = op_active(&r.ops[r.cursor], r.inst_slices);
        self.active_sum += new_active;
        self.schedule_current(id, h);
        observed.map(|(iter, obs, mem_gb)| SimEvent::MemObserved {
            job: id,
            instance,
            iter,
            obs,
            mem_gb,
        })
    }

    /// Kill a running iterative job on an external predictive-restart
    /// decision (the paper's early restart, decided by the
    /// orchestrator's belief ledger in response to
    /// [`SimEvent::MemObserved`]). No simulated time passes; the
    /// returned [`SimEvent::Preempted`] is what the policy consumes.
    pub fn preempt(&mut self, job: JobId, iter: usize, predicted_peak_gb: f64) -> SimEvent {
        let h = self
            .handle_of(job)
            .expect("preempt of a job that is not running");
        self.counters.early_restarts += 1;
        self.kill(
            job,
            h,
            KillKind::Preempt {
                iter,
                peak: predicted_peak_gb,
            },
        )
    }

    fn kill(&mut self, id: JobId, h: Handle, kind: KillKind) -> SimEvent {
        let r = self.remove(h);
        match kind {
            KillKind::Oom { iter, mem_gb } => SimEvent::Oom {
                job: id,
                spec: r.spec,
                instance: r.instance,
                submit_time: r.submit_time,
                iter,
                mem_gb,
            },
            KillKind::Preempt { iter, peak } => SimEvent::Preempted {
                job: id,
                spec: r.spec,
                instance: r.instance,
                submit_time: r.submit_time,
                iter,
                predicted_peak_gb: peak,
            },
        }
    }

    // ---------------------------------------------- checkpoint layer

    /// Serialize the complete engine state — clock, running jobs, both
    /// event calendars, fair-queueing state, accumulators, counters,
    /// records, and the partition manager — into a plain JSON snapshot.
    /// Deterministic bytes: jobs sort by `JobId`, calendar entries by
    /// their `(t, job, token)` key, and stale (lazily-invalidated)
    /// calendar entries are filtered out, so
    /// `restore(snapshot(x))` re-snapshots byte-identically. The spec,
    /// reachability table, `observe` flag, and scratch buffers are
    /// structural and not serialized.
    pub fn snapshot(&self) -> GpuSimSnapshot {
        use crate::util::snap::{f64_to_json, u64_to_json};
        use crate::util::Json;
        let mut jobs: Vec<(JobId, &Running)> =
            self.running.iter().map(|(_, (id, r))| (*id, r)).collect();
        jobs.sort_unstable_by_key(|&(id, _)| id);
        let running = Json::Arr(
            jobs.iter()
                .map(|(id, r)| Json::Arr(vec![Json::num(*id as f64), running_to_json(r)]))
                .collect(),
        );
        GpuSimSnapshot(Json::obj(vec![
            ("now", f64_to_json(self.now)),
            ("running", running),
            ("cal", cal_to_json(&self.cal, &self.running)),
            ("vcal", cal_to_json(&self.vcal, &self.running)),
            ("v_now", f64_to_json(self.v_now)),
            ("n_bw", Json::num(self.n_bw as f64)),
            ("active_sum", f64_to_json(self.active_sum)),
            ("mem_sum", f64_to_json(self.mem_sum)),
            ("token_counter", u64_to_json(self.token_counter)),
            (
                "reconfig_due",
                match self.reconfig_due {
                    Some(t) => f64_to_json(t),
                    None => Json::Null,
                },
            ),
            ("next_id", Json::num(self.next_id as f64)),
            ("energy_j", f64_to_json(self.energy_j)),
            ("mem_gb_integral", f64_to_json(self.mem_gb_integral)),
            ("cost_usd", f64_to_json(self.cost_usd)),
            ("counters", counters_to_json(&self.counters)),
            ("records", records_to_json(&self.records)),
            ("mgr", self.mgr.snapshot().0),
        ]))
    }

    /// Inverse of [`Self::snapshot`]: overwrite the engine state with
    /// the snapshot's. The sim must have been built for the same
    /// [`GpuSpec`]; continuation from the restored state is bit-exact
    /// (asserted end-to-end by `sim::resume_difftest`).
    pub fn restore(&mut self, snap: &GpuSimSnapshot) -> anyhow::Result<()> {
        use crate::util::snap::{f64_from_json, u64_from_json, usize_from_json};
        let j = &snap.0;
        self.mgr
            .restore(&crate::mig::PartitionSnapshot(j.get("mgr").clone()))?;
        let mut running: Slab<(JobId, Running)> = Slab::new();
        let mut by_instance = HashMap::new();
        // JobId -> fresh slab handle, to rehydrate calendar keys (slot
        // assignment is run-local and never serialized).
        let mut handles: HashMap<JobId, Handle> = HashMap::new();
        for row in j
            .get("running")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected running array"))?
        {
            let id: JobId = usize_from_json(row.at(0))?;
            let r = running_from_json(row.at(1))?;
            let instance = r.instance;
            let h = running.insert((id, r));
            by_instance.insert(instance, h);
            let prev = handles.insert(id, h);
            anyhow::ensure!(prev.is_none(), "duplicate job id {id} in snapshot");
        }
        self.cal = cal_from_json(j.get("cal"), &handles)?;
        self.vcal = cal_from_json(j.get("vcal"), &handles)?;
        self.running = running;
        self.by_instance = by_instance;
        self.now = f64_from_json(j.get("now"))?;
        self.v_now = f64_from_json(j.get("v_now"))?;
        self.n_bw = usize_from_json(j.get("n_bw"))?;
        self.active_sum = f64_from_json(j.get("active_sum"))?;
        self.mem_sum = f64_from_json(j.get("mem_sum"))?;
        self.token_counter = u64_from_json(j.get("token_counter"))?;
        self.reconfig_due = if j.get("reconfig_due").is_null() {
            None
        } else {
            Some(f64_from_json(j.get("reconfig_due"))?)
        };
        self.next_id = usize_from_json(j.get("next_id"))?;
        self.energy_j = f64_from_json(j.get("energy_j"))?;
        self.mem_gb_integral = f64_from_json(j.get("mem_gb_integral"))?;
        // Pre-power-subsystem snapshots have no cost key: 0.0.
        self.cost_usd = if j.get("cost_usd").is_null() {
            0.0
        } else {
            f64_from_json(j.get("cost_usd"))?
        };
        self.counters = counters_from_json(j.get("counters"))?;
        self.records = records_from_json(j.get("records"))?;
        self.due_scratch.clear();
        Ok(())
    }

    // --------------------------------------------------- fault layer

    /// Fault-injection: the GPU dies right now. Every running job is
    /// unwound (ascending `JobId` order — deterministic) and returned
    /// as `(id, spec, original_submit_time)` for the orchestrator to
    /// re-queue; both calendars and any open reconfiguration window are
    /// dropped. Energy/memory integrals and completion records survive
    /// (work already done happened). `remove` squashes the activity
    /// accumulators to exactly zero when the last job leaves, so a
    /// later restart resumes from a clean engine.
    pub fn fault_evacuate(&mut self) -> Vec<(JobId, JobSpec, f64)> {
        let mut ids: Vec<(JobId, Handle)> =
            self.running.iter().map(|(h, (id, _))| (*id, h)).collect();
        ids.sort_unstable_by_key(|&(id, _)| id);
        let mut out = Vec::with_capacity(ids.len());
        for (id, h) in ids {
            let r = self.remove(h);
            out.push((id, r.spec, r.submit_time));
        }
        self.cal.clear();
        self.vcal.clear();
        self.due_scratch.clear();
        self.reconfig_due = None;
        out
    }

    /// Advance a dead (evacuated, powered-off) GPU's clock to `t`
    /// **without** accruing energy — a down GPU draws nothing, unlike
    /// [`idle_until`](Self::idle_until)'s idle-power floor. Used by the
    /// orchestrator while the GPU is down and at the restore instant.
    pub fn power_on_at(&mut self, t: f64) {
        assert!(
            self.running.is_empty() && self.reconfig_due.is_none(),
            "power_on_at on a busy sim"
        );
        if t > self.now {
            self.now = t;
        }
    }

    /// Test hook: inject a job whose op program is already exhausted
    /// (the dt=∞ regression class — unreachable via `launch`, which
    /// always compiles a non-empty program).
    #[cfg(test)]
    pub(crate) fn inject_empty_job_for_test(
        &mut self,
        spec: JobSpec,
        instance: InstanceId,
        submit_time: f64,
    ) -> JobId {
        assert!(!self.running_on(instance));
        let c = self.mgr.compute_slices_of(instance).unwrap();
        let inst_mem = self.mgr.mem_gb_of(instance).unwrap();
        let mut r = Running::launch(spec, instance, inst_mem, c, self.now, submit_time);
        r.ops.clear();
        let id = self.next_id;
        self.next_id += 1;
        let h = self.running.insert((id, r));
        self.by_instance.insert(instance, h);
        self.schedule_current(id, h);
        id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::rodinia;

    fn sim() -> GpuSim {
        GpuSim::new(Arc::new(GpuSpec::a100_40gb()), false)
    }

    fn full_profile(sim: &GpuSim) -> usize {
        sim.spec.profile_index("7g.40gb").unwrap()
    }

    #[test]
    fn single_job_on_full_gpu_matches_ideal_runtime() {
        let mut s = sim();
        let prof = full_profile(&s);
        let inst = s.mgr.alloc(prof).unwrap();
        let job = rodinia::by_name("nw").unwrap().job(7);
        let ideal = job.baseline_runtime_s(7);
        s.launch(job, inst, 0.0);
        let mut finished = false;
        while let Some(ev) = s.advance() {
            if matches!(ev, SimEvent::Finished { .. }) {
                finished = true;
            }
        }
        assert!(finished);
        assert!(
            (s.now() - ideal).abs() < 1e-6,
            "sim {} vs ideal {}",
            s.now(),
            ideal
        );
    }

    #[test]
    fn energy_bounded_by_idle_and_max_power() {
        let mut s = sim();
        let prof = full_profile(&s);
        let inst = s.mgr.alloc(prof).unwrap();
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), inst, 0.0);
        while s.advance().is_some() {}
        let idle_floor = s.spec.idle_power_w * s.now();
        assert!(s.energy_j() >= idle_floor - 1e-6);
        assert!(s.energy_j() < s.spec.max_power_w * s.now() + 1e-6);
    }

    #[test]
    fn seven_concurrent_kernel_jobs_are_nearly_7x() {
        // gaussian is kernel-bound: 7 concurrent small slices should be
        // close to 7x throughput of sequential execution.
        let job = rodinia::by_name("gaussian").unwrap().job(7);
        // sequential on the full GPU
        let mut base = sim();
        let prof = full_profile(&base);
        let inst = base.mgr.alloc(prof).unwrap();
        for _ in 0..7 {
            base.launch(job.clone(), inst, 0.0);
            loop {
                match base.advance() {
                    Some(SimEvent::Finished { .. }) => break,
                    Some(_) => {}
                    None => panic!("job lost"),
                }
            }
        }
        let t_seq = base.now();
        // concurrent on 7 x 1g.5gb
        let mut mig = sim();
        for _ in 0..7 {
            let i = mig.mgr.alloc(0).unwrap();
            mig.launch(job.clone(), i, 0.0);
        }
        let mut n = 0;
        while let Some(ev) = mig.advance() {
            if matches!(ev, SimEvent::Finished { .. }) {
                n += 1;
            }
        }
        assert_eq!(n, 7);
        let speedup = t_seq / mig.now();
        assert!(speedup > 5.0, "speedup {speedup}");
    }

    #[test]
    fn pcie_bound_jobs_contend() {
        // nw has a large bandwidth-bound transfer share: 7 concurrent
        // copies must each run noticeably slower than solo (Table 4),
        // but far better than sequential.
        let job = rodinia::by_name("nw").unwrap().job(7);
        let mut solo = sim();
        let i = solo.mgr.alloc(0).unwrap();
        solo.launch(job.clone(), i, 0.0);
        while solo.advance().is_some() {}
        let t_solo = solo.now();

        let mut shared = sim();
        for _ in 0..7 {
            let i = shared.mgr.alloc(0).unwrap();
            shared.launch(job.clone(), i, 0.0);
        }
        while shared.advance().is_some() {}
        let per_job = shared.now();
        assert!(
            per_job > t_solo * 1.35,
            "contended {per_job} vs solo {t_solo}"
        );
        assert!(per_job < t_solo * 5.0);
    }

    #[test]
    fn alloc_overhead_grows_with_instances() {
        // Table 3: myocyte alloc 0.24s alone -> ~0.98s with 7 slices.
        // Overheads are applied when the op is armed, with the live
        // instance count.
        let spec = GpuSpec::a100_40gb();
        let job = rodinia::by_name("myocyte").unwrap().job(7);
        let mut ops = compile_ops(&job, 1);
        arm_op(&mut ops[0], &spec, 7);
        match &ops[0] {
            Op::Fixed { rem, .. } => {
                assert!((rem - 0.96).abs() < 0.05, "alloc {rem} expected ~0.98")
            }
            _ => panic!("first op must be alloc"),
        }
        // armed solo, the base value is unchanged
        let mut solo = compile_ops(&job, 1);
        arm_op(&mut solo[0], &spec, 1);
        match &solo[0] {
            Op::Fixed { rem, .. } => assert!((rem - 0.24).abs() < 0.01, "alloc {rem}"),
            _ => unreachable!(),
        }
    }

    #[test]
    fn op_overheads_track_live_instance_count_across_reconfig() {
        // A job that spans a layout change pays free/transfer overheads
        // of the layout each op *starts* under — not the launch layout.
        let job = rodinia::by_name("gaussian").unwrap().job(7);
        let (p, bw_frac) = match (&job.compute, bw_fraction(&job)) {
            (ComputeModel::Phases(p), f) => (*p, f),
            _ => unreachable!(),
        };
        // control: the count stays 1 for the whole run
        let mut a = sim();
        let ia = a.mgr.alloc(0).unwrap();
        a.launch(job.clone(), ia, 0.0);
        while a.advance().is_some() {}
        let t_a = a.now();
        // treatment: 6 extra instances appear mid-kernel
        let mut b = sim();
        let ib = b.mgr.alloc(0).unwrap();
        b.launch(job.clone(), ib, 0.0);
        let waves = 7.0; // demand 7 on a 1-GPC slice
        let t_mid = p.alloc_s + p.h2d_pcie_s + 0.5 * p.step_s * waves * p.steps as f64;
        assert!(b.advance_with_horizon(Some(t_mid)).is_none());
        assert!((b.now() - t_mid).abs() < 1e-9);
        for _ in 0..6 {
            b.mgr.alloc(0).unwrap();
        }
        while b.advance().is_some() {}
        let t_b = b.now();
        // only the ops armed after t_mid inflate: d2h fixed part + free
        let delta = p.d2h_pcie_s * (1.0 - bw_frac) * XFER_INSTANCE_OVERHEAD * 6.0
            + b.spec.free_overhead_per_instance_s * 6.0;
        assert!(
            (t_b - t_a - delta).abs() < 1e-9,
            "t_b {t_b} vs t_a {t_a} + delta {delta}"
        );
    }

    #[test]
    fn exhausted_op_program_finishes_instead_of_poisoning_energy() {
        // Regression: a running job with no current op used to leave
        // dt = ∞ guarded only by a debug_assert!, so a release build
        // integrated `power * ∞` into energy (NaN). Exhausted programs
        // are now due immediately and finish cleanly.
        let mut s = sim();
        let inst = s.mgr.alloc(0).unwrap();
        let job = rodinia::by_name("gaussian").unwrap().job(7);
        s.inject_empty_job_for_test(job, inst, 0.0);
        let ev = s.advance().expect("empty program must still finish");
        assert!(matches!(ev, SimEvent::Finished { .. }));
        assert!(s.advance().is_none());
        assert!(s.energy_j().is_finite());
        assert!(s.now().is_finite());
        assert_eq!(s.records.len(), 1);
        assert!((s.records[0].finish_time - 0.0).abs() < 1e-12);
    }

    #[test]
    fn iterative_job_ooms_at_trace_crossing() {
        use crate::workloads::llm;
        let mut s = sim();
        // 2g.10gb slice: qwen2 crosses 10GB near iteration 94.
        let inst = s.mgr.alloc(1).unwrap();
        let job = llm::qwen2_7b().job(7);
        s.launch(job, inst, 0.0);
        let mut oom = None;
        while let Some(ev) = s.advance() {
            if let SimEvent::Oom { iter, mem_gb, .. } = ev {
                oom = Some((iter, mem_gb));
                break;
            }
        }
        let (iter, mem) = oom.expect("must OOM on 10GB");
        assert!((80..=105).contains(&iter), "oom at {iter}");
        assert!(mem > 10.0);
        assert_eq!(s.counters.oom_restarts, 1);
    }

    #[test]
    fn emitted_observations_drive_external_preemption() {
        // The engine emits per-iteration observations; the caller (here
        // a bare monitor standing in for the orchestrator's belief
        // ledger) converges and preempts long before the real OOM.
        use crate::predictor::{ConvergenceCfg, JobMonitor, PredictionOutcome};
        use crate::workloads::llm;
        let mut s = GpuSim::new(Arc::new(GpuSpec::a100_40gb()), true);
        let inst = s.mgr.alloc(1).unwrap(); // 10GB
        let job = llm::qwen2_7b().job(7);
        let n_iters = match &job.compute {
            ComputeModel::Iterative(it) => it.trace.n_iters,
            _ => unreachable!(),
        };
        s.launch(job, inst, 0.0);
        let mut mon = JobMonitor::new(n_iters, ConvergenceCfg::default());
        let mut preempt = None;
        while let Some(ev) = s.advance() {
            match ev {
                SimEvent::MemObserved { job, iter, obs, .. } => {
                    if let PredictionOutcome::Converged { peak_physical_gb } = mon.push(obs) {
                        if peak_physical_gb > 10.0 + EPS {
                            match s.preempt(job, iter, peak_physical_gb) {
                                SimEvent::Preempted {
                                    iter,
                                    predicted_peak_gb,
                                    ..
                                } => preempt = Some((iter, predicted_peak_gb)),
                                other => panic!("preempt returned {other:?}"),
                            }
                            break;
                        }
                    }
                }
                SimEvent::Oom { iter, .. } => panic!("real OOM at {iter} before prediction"),
                _ => {}
            }
        }
        let (iter, peak) = preempt.expect("prediction must fire");
        assert!(iter <= 15, "preempted at {iter}, expected single digits");
        assert!(peak > 10.0, "peak {peak}");
        assert_eq!(s.counters.early_restarts, 1);
        // the preempted job is fully unwound: nothing left to advance
        assert!(s.advance().is_none());
        assert!(s.energy_j().is_finite());
    }

    #[test]
    fn observation_emission_is_opt_in() {
        use crate::workloads::llm;
        let mut s = GpuSim::new(Arc::new(GpuSpec::a100_40gb()), false);
        let p20 = s.spec.profile_index("3g.20gb").unwrap();
        let inst = s.mgr.alloc(p20).unwrap();
        s.launch(llm::qwen2_7b().job(7), inst, 0.0);
        while let Some(ev) = s.advance() {
            assert!(
                !matches!(ev, SimEvent::MemObserved { .. }),
                "observe=false must keep the event stream observation-free"
            );
        }
        assert_eq!(s.records.len(), 1);
    }

    #[test]
    fn iterative_job_completes_on_big_slice() {
        use crate::workloads::llm;
        let mut s = sim();
        let p20 = s.spec.profile_index("3g.20gb").unwrap();
        let inst = s.mgr.alloc(p20).unwrap();
        s.launch(llm::qwen2_7b().job(7), inst, 0.0);
        let mut ok = false;
        while let Some(ev) = s.advance() {
            match ev {
                SimEvent::Finished { .. } => ok = true,
                SimEvent::Oom { .. } => panic!("must not OOM on 20GB"),
                _ => {}
            }
        }
        assert!(ok);
        assert_eq!(s.records.len(), 1);
    }

    #[test]
    fn static_job_with_underestimate_ooms_at_alloc() {
        let mut s = sim();
        let inst = s.mgr.alloc(0).unwrap(); // 5GB
        let mut job = rodinia::by_name("kmeans").unwrap().job(7); // 6GB true
        job.est = job.est.with_point(4.0); // force a mis-estimate
        s.launch(job, inst, 0.0);
        let mut oom = false;
        while let Some(ev) = s.advance() {
            if matches!(ev, SimEvent::Oom { .. }) {
                oom = true;
            }
        }
        assert!(oom);
    }

    #[test]
    fn reconfig_window_blocks_and_completes() {
        let mut s = sim();
        s.begin_reconfig(3);
        assert!(s.is_reconfiguring());
        let ev = s.advance().unwrap();
        assert!(matches!(ev, SimEvent::ReconfigDone));
        assert!((s.now() - 3.0 * s.spec.reconfig_op_s).abs() < 1e-9);
        assert_eq!(s.counters.reconfig_ops, 3);
        assert_eq!(s.counters.reconfig_windows, 1);
        assert!((s.counters.reconfig_time_s - 3.0 * s.spec.reconfig_op_s).abs() < 1e-12);
    }

    #[test]
    fn timed_reconfig_window_charges_the_modeled_cost() {
        // A plan-priced window: arbitrary duration, op count tracked
        // separately; zero-op/zero-duration calls open no window.
        let mut s = sim();
        s.begin_reconfig_window(0.0, 0);
        assert!(!s.is_reconfiguring());
        assert_eq!(s.counters.reconfig_windows, 0);
        s.begin_reconfig_window(0.75, 4);
        assert!(s.is_reconfiguring());
        let ev = s.advance().unwrap();
        assert!(matches!(ev, SimEvent::ReconfigDone));
        assert!((s.now() - 0.75).abs() < 1e-9);
        assert_eq!(s.counters.reconfig_ops, 4);
        assert_eq!(s.counters.reconfig_windows, 1);
        assert!((s.counters.reconfig_time_s - 0.75).abs() < 1e-12);
        // idle energy accrued during the window
        assert!((s.energy_j() - 0.75 * s.spec.idle_power_w).abs() < 1e-9);
    }

    #[test]
    fn mem_utilization_integral_positive_and_bounded() {
        let mut s = sim();
        let inst = s.mgr.alloc(0).unwrap();
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), inst, 0.0);
        while s.advance().is_some() {}
        let util = s.mem_gb_integral() / (s.now() * s.spec.total_mem_gb);
        assert!(util > 0.0 && util < 1.0, "{util}");
    }

    #[test]
    fn horizon_clips_the_clock_without_losing_work() {
        let job = rodinia::by_name("gaussian").unwrap().job(7);
        // reference: run to completion without a horizon
        let mut a = sim();
        let i = a.mgr.alloc(0).unwrap();
        a.launch(job.clone(), i, 0.0);
        while a.advance().is_some() {}
        let t_ref = a.now();
        // same run, interrupted at an arbitrary horizon mid-flight
        let mut b = sim();
        let i = b.mgr.alloc(0).unwrap();
        b.launch(job, i, 0.0);
        let h = t_ref * 0.3;
        let ev = b.advance_with_horizon(Some(h));
        // either an event fired before the horizon or we stopped at it
        if ev.is_none() {
            assert!((b.now() - h).abs() < 1e-9, "stopped at {} not {h}", b.now());
        }
        while b.advance().is_some() {}
        assert!((b.now() - t_ref).abs() < 1e-9, "{} vs {}", b.now(), t_ref);
    }

    #[test]
    fn zero_length_horizon_window_is_a_noop() {
        // An orchestrator step can hand the sim a horizon equal to its
        // current clock; the sim must return immediately without
        // integrating anything or firing events.
        let mut s = sim();
        let i = s.mgr.alloc(0).unwrap();
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), i, 0.0);
        let h = 0.05; // strictly inside the alloc phase
        assert!(s.advance_with_horizon(Some(h)).is_none());
        let (t0, e0) = (s.now(), s.energy_j());
        assert!((t0 - h).abs() < 1e-12);
        for _ in 0..3 {
            assert!(s.advance_with_horizon(Some(h)).is_none());
            assert_eq!(s.now(), t0);
            assert_eq!(s.energy_j(), e0);
        }
        // and the run still completes exactly on schedule
        while s.advance().is_some() {}
        let ideal = rodinia::by_name("gaussian").unwrap().job(7).baseline_runtime_s(1);
        assert!((s.now() - ideal).abs() < 1e-6, "{} vs {ideal}", s.now());
    }

    #[test]
    fn idle_until_charges_idle_power_only() {
        let mut s = sim();
        s.idle_until(10.0);
        assert!((s.now() - 10.0).abs() < 1e-12);
        assert!((s.energy_j() - 10.0 * s.spec.idle_power_w).abs() < 1e-9);
        s.idle_until(5.0); // never goes backwards
        assert!((s.now() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn records_carry_queueing_anchor() {
        let mut s = sim();
        let prof = full_profile(&s);
        let inst = s.mgr.alloc(prof).unwrap();
        s.idle_until(2.0);
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), inst, 0.5);
        while s.advance().is_some() {}
        let r = &s.records[0];
        assert!((r.submit_time - 0.5).abs() < 1e-12);
        assert!((r.start_time - 2.0).abs() < 1e-12);
        assert!(r.finish_time > r.start_time);
    }

    #[test]
    fn clock_is_monotone_across_many_events() {
        let mut s = sim();
        for _ in 0..7 {
            let i = s.mgr.alloc(0).unwrap();
            s.launch(rodinia::by_name("nw").unwrap().job(7), i, 0.0);
        }
        let mut last = 0.0;
        while s.advance().is_some() {
            assert!(s.now() >= last - 1e-12);
            last = s.now();
        }
    }

    #[test]
    fn snapshot_mid_run_resumes_bit_identically() {
        use crate::workloads::llm;
        // Mixed load: a PCIe-contending pair plus an iterative job, cut
        // mid-flight (inside bandwidth sharing), snapshotted through
        // JSON text into a fresh sim, then both runs finish — clocks,
        // energy, records, and re-snapshots must agree to the bit.
        let build = || {
            let mut s = GpuSim::new(Arc::new(GpuSpec::a100_40gb()), true);
            let a = s.mgr.alloc(0).unwrap();
            let b = s.mgr.alloc(0).unwrap();
            let c = s.mgr.alloc(1).unwrap();
            s.launch(rodinia::by_name("nw").unwrap().job(7), a, 0.0);
            s.launch(rodinia::by_name("nw").unwrap().job(7), b, 0.0);
            s.launch(llm::qwen2_7b().job(7), c, 0.0);
            s
        };
        let mut full = build();
        let mut cut = build();
        // burn a few events on both, identically
        for _ in 0..5 {
            full.advance();
            cut.advance();
        }
        let snap = cut.snapshot();
        let text = snap.0.to_string();
        let mut resumed = GpuSim::new(Arc::new(GpuSpec::a100_40gb()), true);
        resumed
            .restore(&GpuSimSnapshot(
                crate::util::Json::parse(&text).unwrap(),
            ))
            .unwrap();
        assert_eq!(
            resumed.snapshot().0.to_string(),
            text,
            "restore() then snapshot() drifted"
        );
        loop {
            let a = full.advance_with_horizon(None);
            let b = resumed.advance_with_horizon(None);
            assert_eq!(a.is_some(), b.is_some(), "event streams diverged");
            assert_eq!(full.now().to_bits(), resumed.now().to_bits());
            if a.is_none() {
                break;
            }
        }
        assert_eq!(full.energy_j().to_bits(), resumed.energy_j().to_bits());
        assert_eq!(full.records.len(), resumed.records.len());
        assert_eq!(
            full.snapshot().0.to_string(),
            resumed.snapshot().0.to_string()
        );
    }

    #[test]
    fn fault_evacuate_unwinds_everything_and_power_on_skips_energy() {
        let mut s = sim();
        let a = s.mgr.alloc(0).unwrap();
        let b = s.mgr.alloc(0).unwrap();
        s.launch(rodinia::by_name("nw").unwrap().job(7), a, 0.0);
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), b, 0.5);
        for _ in 0..3 {
            s.advance_with_horizon(Some(1.0));
        }
        let lost = s.fault_evacuate();
        assert_eq!(lost.len(), 2);
        assert_eq!(lost[0].0, 0, "evacuation is JobId-ordered");
        assert_eq!(lost[1].0, 1);
        assert!((lost[1].2 - 0.5).abs() < 1e-12, "submit time preserved");
        assert_eq!(s.n_running(), 0);
        assert!(!s.is_reconfiguring());
        assert!(s.advance().is_none(), "nothing left to simulate");
        // dead clock advance: time moves, energy does not
        let e = s.energy_j();
        let t = s.now();
        s.power_on_at(t + 10.0);
        assert!((s.now() - (t + 10.0)).abs() < 1e-12);
        assert_eq!(s.energy_j().to_bits(), e.to_bits());
        // the engine is reusable after the reboot
        s.mgr.wipe();
        let i = s.mgr.alloc(0).unwrap();
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), i, s.now());
        while s.advance().is_some() {}
        assert_eq!(s.records.len(), 1);
    }

    #[test]
    fn per_instance_attribution_sums_to_engine_draw_while_running() {
        use crate::power::Calibration;
        // Under every model variant, the public breakdown must sum to
        // the draw the engine is integrating, at every event boundary.
        let base = GpuSpec::a100_40gb();
        let models = [
            PowerModel::Legacy,
            PowerModel::SliceProportional,
            PowerModel::Measured(Calibration::default_for(&base)),
        ];
        for model in models {
            let spec = Arc::new(GpuSpec::a100_40gb().with_power_model(model.clone()));
            let mut s = GpuSim::new(spec, false);
            let a = s.mgr.alloc(0).unwrap();
            let b = s.mgr.alloc(1).unwrap();
            s.launch(rodinia::by_name("nw").unwrap().job(7), a, 0.0);
            s.launch(rodinia::by_name("gaussian").unwrap().job(3), b, 0.0);
            loop {
                let bd = s.power_breakdown();
                let total = s.current_power_w();
                assert!(
                    (bd.total_w() - total).abs() <= 1e-9 * total.max(1.0),
                    "{}: {} vs {total}",
                    model.name(),
                    bd.total_w()
                );
                assert_eq!(bd.per_instance.len(), 2);
                assert_eq!(s.instance_power_w(a), bd.instance_w(a));
                assert!(s.instance_power_w(a).unwrap() >= 0.0);
                // Reservation bounds the actual draw at every instant.
                assert!(s.power_reservation_w() >= total - 1e-9);
                if s.advance().is_none() {
                    break;
                }
            }
            assert!(s.energy_j().is_finite() && s.energy_j() > 0.0);
        }
    }

    #[test]
    fn legacy_energy_is_bitwise_unchanged_by_the_model_plumbing() {
        // The Legacy arm must reproduce the pre-subsystem curve bit for
        // bit: same expression, same accumulator. Sanity-pin it against
        // a hand-integrated run of the same mix.
        let mut s = sim();
        let a = s.mgr.alloc(0).unwrap();
        s.launch(rodinia::by_name("gaussian").unwrap().job(7), a, 0.0);
        while s.advance().is_some() {}
        let per_gpc =
            (s.spec.max_power_w - s.spec.idle_power_w) / s.spec.total_compute as f64;
        // Solo job on a 1-GPC slice: active is util·1 per op; the
        // energy must sit between the idle floor and idle+per_gpc.
        assert!(s.energy_j() >= s.spec.idle_power_w * s.now() - 1e-9);
        assert!(s.energy_j() <= (s.spec.idle_power_w + per_gpc) * s.now() + 1e-9);
        // And cost stays exactly 0.0 with no signal attached.
        assert_eq!(s.cost_usd(), 0.0);
    }

    #[test]
    fn slice_proportional_draws_at_least_legacy() {
        // Occupancy-based draw upper-bounds the utilization-scaled
        // legacy curve (active_i <= slices_i · occupied_i), so the
        // integrated energy must too.
        let run = |model: PowerModel| {
            let spec = Arc::new(GpuSpec::a100_40gb().with_power_model(model));
            let mut s = GpuSim::new(spec, false);
            let a = s.mgr.alloc(0).unwrap();
            let b = s.mgr.alloc(1).unwrap();
            s.launch(rodinia::by_name("nw").unwrap().job(7), a, 0.0);
            s.launch(rodinia::by_name("myocyte").unwrap().job(2), b, 0.0);
            while s.advance().is_some() {}
            (s.now(), s.energy_j())
        };
        let (t_legacy, e_legacy) = run(PowerModel::Legacy);
        let (t_miso, e_miso) = run(PowerModel::SliceProportional);
        // The model never changes timing — only the draw.
        assert_eq!(t_legacy.to_bits(), t_miso.to_bits());
        assert!(e_miso >= e_legacy - 1e-9, "{e_miso} vs {e_legacy}");
    }

    #[test]
    fn projection_matches_reservation_after_the_launch() {
        let mut s = sim();
        let a = s.mgr.alloc(1).unwrap(); // 2g.10gb
        let job = rodinia::by_name("gaussian").unwrap().job(2);
        let projected = s.power_projection_w(a, job.demand_gpcs);
        s.launch(job, a, 0.0);
        assert_eq!(projected.to_bits(), s.power_reservation_w().to_bits());
    }

    #[test]
    fn flat_price_cost_tracks_energy_exactly() {
        let mut priced = sim();
        priced.set_price_signal(Some(PriceSignal::Flat(0.20)));
        let mut plain = sim();
        for s in [&mut priced, &mut plain] {
            let i = s.mgr.alloc(0).unwrap();
            s.launch(rodinia::by_name("nw").unwrap().job(7), i, 0.0);
            while s.advance().is_some() {}
            s.idle_until(s.now() + 50.0);
        }
        // The signal changes nothing about the run itself...
        assert_eq!(priced.now().to_bits(), plain.now().to_bits());
        assert_eq!(priced.energy_j().to_bits(), plain.energy_j().to_bits());
        assert_eq!(plain.cost_usd(), 0.0);
        // ...and under a flat tariff, $ = price · kWh.
        let expect = 0.20 * priced.energy_j() / 3.6e6;
        assert!(
            (priced.cost_usd() - expect).abs() <= 1e-12 + 1e-9 * expect,
            "{} vs {expect}",
            priced.cost_usd()
        );
        // Cost survives the snapshot round-trip.
        let snap = priced.snapshot();
        let mut back = sim();
        back.restore(&snap).unwrap();
        assert_eq!(back.cost_usd().to_bits(), priced.cost_usd().to_bits());
    }

    #[test]
    fn simultaneous_completions_fire_in_job_id_order() {
        // Seven identical jobs complete at the same instant; the
        // deterministic (time, JobId) tie-break fires them in launch
        // order.
        let mut s = sim();
        for _ in 0..7 {
            let i = s.mgr.alloc(0).unwrap();
            s.launch(rodinia::by_name("gaussian").unwrap().job(7), i, 0.0);
        }
        let mut order = Vec::new();
        while let Some(ev) = s.advance() {
            if let SimEvent::Finished { job, .. } = ev {
                order.push(job);
            }
        }
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5, 6]);
    }
}
