//! Differential property tests: the indexed engine ([`GpuSim`]) against
//! the scan-and-decrement oracle ([`naive::NaiveGpuSim`]).
//!
//! Both engines are driven in lockstep with identical stimuli — same
//! launches, same random horizons, same mid-run reconfiguration windows
//! and instance-count changes, same OOM/early-restart relaunches — and
//! must produce the **same event sequence** (kind, job id, instance,
//! iteration, allocator observation) with clocks, energy, and memory
//! integrals agreeing within `REL_TOL = 1e-6` relative tolerance. The
//! tolerance exists because the oracle *decrements* remaining times per
//! event while the indexed engine schedules *absolute* instants; the
//! two accumulate float rounding differently (well below 1e-9 per event
//! in practice).
//!
//! Prediction is driven the way the orchestrator drives it: the
//! harness owns the [`JobMonitor`]s, consumes the engines' emitted
//! [`SimEvent::MemObserved`] observations, and preempts *both* engines
//! at the instant a projection converges above the slice.

use std::collections::HashMap;
use std::sync::Arc;

use crate::mig::{GpuSpec, InstanceId};
use crate::predictor::{ConvergenceCfg, JobMonitor, PredictionOutcome};
use crate::util::Rng;
use crate::workloads::{llm, mix, ComputeModel, JobKind, JobSpec};

use super::naive::NaiveGpuSim;
use super::{EPS, GpuSim, JobId, SimEvent};

/// Documented agreement tolerance between the two engines (relative).
const REL_TOL: f64 = 1e-6;

fn assert_close(what: &str, x: f64, y: f64) {
    let tol = REL_TOL * (1.0 + x.abs().max(y.abs()));
    assert!((x - y).abs() <= tol, "{what}: indexed {x} vs oracle {y}");
}

/// Equivalence of one event pair: same kind, job, instance, iteration.
fn assert_events_equiv(x: &SimEvent, y: &SimEvent) {
    match (x, y) {
        (
            SimEvent::Finished {
                job: ja,
                instance: ia,
                ..
            },
            SimEvent::Finished {
                job: jb,
                instance: ib,
                ..
            },
        ) => assert_eq!((ja, ia), (jb, ib), "finish mismatch"),
        (
            SimEvent::Oom {
                job: ja,
                instance: ia,
                iter: ta,
                ..
            },
            SimEvent::Oom {
                job: jb,
                instance: ib,
                iter: tb,
                ..
            },
        ) => assert_eq!((ja, ia, ta), (jb, ib, tb), "oom mismatch"),
        (
            SimEvent::Preempted {
                job: ja,
                instance: ia,
                iter: ta,
                ..
            },
            SimEvent::Preempted {
                job: jb,
                instance: ib,
                iter: tb,
                ..
            },
        ) => assert_eq!((ja, ia, ta), (jb, ib, tb), "preempt mismatch"),
        (
            SimEvent::MemObserved {
                job: ja,
                instance: ia,
                iter: ta,
                obs: oa,
                mem_gb: ma,
            },
            SimEvent::MemObserved {
                job: jb,
                instance: ib,
                iter: tb,
                obs: ob,
                mem_gb: mb,
            },
        ) => {
            assert_eq!((ja, ia, ta), (jb, ib, tb), "observation mismatch");
            assert_eq!(oa, ob, "observation payload mismatch");
            assert_eq!(ma.to_bits(), mb.to_bits(), "observed mem mismatch");
        }
        (SimEvent::ReconfigDone, SimEvent::ReconfigDone) => {}
        _ => panic!("event kind mismatch: {x:?} vs {y:?}"),
    }
}

fn ev_instance(ev: &SimEvent) -> Option<InstanceId> {
    match ev {
        SimEvent::Finished { instance, .. }
        | SimEvent::Oom { instance, .. }
        | SimEvent::Preempted { instance, .. }
        | SimEvent::MemObserved { instance, .. } => Some(*instance),
        SimEvent::ReconfigDone => None,
    }
}

fn is_kill(ev: &SimEvent) -> bool {
    matches!(ev, SimEvent::Oom { .. } | SimEvent::Preempted { .. })
}

fn ev_spec(ev: &SimEvent) -> Option<&JobSpec> {
    match ev {
        SimEvent::Finished { spec, .. }
        | SimEvent::Oom { spec, .. }
        | SimEvent::Preempted { spec, .. } => Some(spec),
        SimEvent::MemObserved { .. } | SimEvent::ReconfigDone => None,
    }
}

/// The monitor the orchestrator's ledger would open for this launch
/// (fresh per launch, LLM-only, prediction-gated), plus the launch
/// slice's capacity — the preemption threshold.
fn monitor_for(job: &JobSpec, prediction: bool, cap_gb: f64) -> Option<(JobMonitor, f64)> {
    match (&job.compute, prediction, job.kind) {
        (ComputeModel::Iterative(it), true, JobKind::Llm) => Some((
            JobMonitor::new(it.trace.n_iters, ConvergenceCfg::default()),
            cap_gb,
        )),
        _ => None,
    }
}

/// Drive both engines in lockstep over `jobs` on `profile`-sized
/// instances, with seeded random horizons, reconfiguration windows,
/// instance-count changes, and kill-relaunches. Panics on the first
/// divergence.
fn lockstep(spec: Arc<GpuSpec>, profile: usize, jobs: &[JobSpec], prediction: bool, seed: u64) {
    let mut a = GpuSim::new(spec.clone(), prediction);
    let mut b = NaiveGpuSim::new(spec.clone(), prediction);
    // Fill the GPU with `profile` instances (identically on both).
    let mut insts = Vec::new();
    while let Ok(i) = a.mgr.alloc(profile) {
        assert_eq!(b.mgr.alloc(profile).unwrap(), i);
        insts.push(i);
    }
    assert!(!insts.is_empty(), "profile {profile} must fit the GPU");
    let mut backlog: Vec<JobSpec> = jobs.to_vec();
    backlog.reverse();
    // Harness-owned prediction state, one monitor per live launch.
    let mut mons: HashMap<JobId, (JobMonitor, f64)> = HashMap::new();
    for &inst in &insts {
        let Some(job) = backlog.pop() else { break };
        let cap = a.mgr.mem_gb_of(inst).unwrap();
        let id = a.launch(job.clone(), inst, 0.0);
        assert_eq!(id, b.launch(job.clone(), inst, 0.0));
        if let Some(mc) = monitor_for(&job, prediction, cap) {
            mons.insert(id, mc);
        }
    }

    let mut rng = Rng::new(seed);
    let mut extras: Vec<InstanceId> = Vec::new();
    let mut relaunches = 0usize;
    let mut steps = 0usize;
    loop {
        steps += 1;
        assert!(steps < 1_000_000, "lockstep did not converge");
        let (ea, eb) = if rng.below(4) == 0 {
            let h = a.now() + rng.f64() * 5.0;
            (
                a.advance_with_horizon(Some(h)),
                b.advance_with_horizon(Some(h)),
            )
        } else {
            (a.advance(), b.advance())
        };
        match (ea, eb) {
            (None, None) => {
                assert_eq!(a.n_running(), b.n_running(), "running-set size diverged");
                assert_eq!(a.is_reconfiguring(), b.is_reconfiguring());
                assert_close("clock at horizon", a.now(), b.now());
                if a.n_running() == 0 && !a.is_reconfiguring() {
                    break;
                }
            }
            (Some(x), Some(y)) => {
                assert_events_equiv(&x, &y);
                assert_close("event clock", a.now(), b.now());
                // Drive prediction exactly like the orchestrator: push
                // the emitted observation into the harness monitor and,
                // on a projection converging above the slice, preempt
                // BOTH engines at this very instant. The preemption
                // events then flow through the kill-relaunch logic
                // below in place of the observation.
                let mut preempt_req = None;
                if let SimEvent::MemObserved { job, iter, obs, .. } = &x {
                    if let Some((mon, cap)) = mons.get_mut(job) {
                        if let PredictionOutcome::Converged { peak_physical_gb } = mon.push(*obs)
                        {
                            if peak_physical_gb > *cap + EPS {
                                preempt_req = Some((*job, *iter, peak_physical_gb));
                            }
                        }
                    }
                }
                let x = match preempt_req {
                    Some((j, it_, peak)) => {
                        mons.remove(&j);
                        let ka = a.preempt(j, it_, peak);
                        let kb = b.preempt(j, it_, peak);
                        assert_events_equiv(&ka, &kb);
                        ka
                    }
                    None => x,
                };
                // Killed jobs drop their monitors (stale ids are never
                // reused, but keep the map tight).
                if is_kill(&x) {
                    if let SimEvent::Oom { job, .. } | SimEvent::Preempted { job, .. } = &x {
                        mons.remove(job);
                    }
                }
                // Backlog drains onto freed instances (a FIFO in
                // miniature: launches at t > 0, staggered arming).
                if matches!(x, SimEvent::Finished { .. }) {
                    if let (Some(inst), Some(job)) = (ev_instance(&x), backlog.pop()) {
                        let t = a.now();
                        let cap = a.mgr.mem_gb_of(inst).unwrap();
                        let id = a.launch(job.clone(), inst, t);
                        assert_eq!(id, b.launch(job.clone(), inst, t));
                        if let Some(mc) = monitor_for(&job, prediction, cap) {
                            mons.insert(id, mc);
                        }
                    }
                }
                // Killed jobs occasionally restart in place (the
                // paper's OOM-restart path), with a global bound so a
                // chronically-too-big job cannot loop forever.
                if is_kill(&x) && relaunches < 6 && rng.below(2) == 0 {
                    if let (Some(inst), Some(job)) = (ev_instance(&x), ev_spec(&x)) {
                        let (job, t) = (job.clone(), a.now());
                        let cap = a.mgr.mem_gb_of(inst).unwrap();
                        let id = a.launch(job.clone(), inst, t);
                        assert_eq!(id, b.launch(job.clone(), inst, t));
                        if let Some(mc) = monitor_for(&job, prediction, cap) {
                            mons.insert(id, mc);
                        }
                        relaunches += 1;
                    }
                }
                // Random mid-run perturbations, mirrored on both sims.
                match rng.below(8) {
                    0 if !a.is_reconfiguring() => {
                        let d = rng.f64() * 0.4;
                        a.begin_reconfig_window(d, 1);
                        b.begin_reconfig_window(d, 1);
                    }
                    1 => {
                        // Layout change: the live instance count shifts,
                        // so later-armed ops pay different overheads.
                        match (a.mgr.alloc(profile), b.mgr.alloc(profile)) {
                            (Ok(i), Ok(j)) => {
                                assert_eq!(i, j);
                                extras.push(i);
                            }
                            (Err(_), Err(_)) => {}
                            _ => panic!("managers diverged on alloc"),
                        }
                    }
                    2 => {
                        if let Some(i) = extras.pop() {
                            a.mgr.free(i).unwrap();
                            b.mgr.free(i).unwrap();
                        }
                    }
                    _ => {}
                }
            }
            (x, y) => panic!("event presence diverged: indexed {x:?} vs oracle {y:?}"),
        }
    }

    // Final-state agreement.
    assert_close("makespan", a.now(), b.now());
    assert_close("energy", a.energy_j(), b.energy_j());
    assert_close("mem integral", a.mem_gb_integral(), b.mem_gb_integral());
    assert_eq!(a.counters.reconfig_ops, b.counters.reconfig_ops);
    assert_eq!(a.counters.reconfig_windows, b.counters.reconfig_windows);
    assert_eq!(a.counters.oom_restarts, b.counters.oom_restarts);
    assert_eq!(a.counters.early_restarts, b.counters.early_restarts);
    assert_close(
        "reconfig time",
        a.counters.reconfig_time_s,
        b.counters.reconfig_time_s,
    );
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.name, rb.name);
        assert_close("record submit", ra.submit_time, rb.submit_time);
        assert_close("record start", ra.start_time, rb.start_time);
        assert_close("record finish", ra.finish_time, rb.finish_time);
    }
}

fn specs() -> Vec<Arc<GpuSpec>> {
    vec![
        Arc::new(GpuSpec::a100_40gb()),
        Arc::new(GpuSpec::a30_24gb()),
        Arc::new(GpuSpec::h100_80gb()),
    ]
}

#[test]
fn property_sweep_static_mixes() {
    // Rodinia/paper mixes on the smallest slice of every GPU model:
    // kernel-bound and transfer-bound jobs, alloc-phase OOMs for
    // anything over the slice, PCIe sharing across all of them.
    for spec in specs() {
        for seed in [1u64, 2, 3] {
            let mixes = [mix::hm2(), mix::ht3(seed), mix::ml1(seed)];
            for m in &mixes {
                lockstep(spec.clone(), 0, &m.jobs, false, seed);
            }
        }
    }
}

#[test]
fn property_sweep_iterative_llm() {
    // Trace-carrying LLM jobs: iteration-level memory checks, OOM at
    // the trace crossing, predictive early restart when enabled.
    for spec in specs() {
        for (seed, prediction) in [(7u64, false), (8, true), (9, true)] {
            let jobs = vec![
                llm::qwen2_7b().job(seed),
                llm::llama3_3b().job(seed + 1),
                llm::flan_t5_infer().job(seed + 2),
                llm::flan_t5_train().job(seed + 3),
            ];
            // profile 1: 10GB-class slices (A30: 12GB) — qwen2 crosses.
            lockstep(spec.clone(), 1, &jobs, prediction, seed);
        }
    }
}

#[test]
fn property_sweep_mixed_pool_on_larger_slices() {
    // Static + iterative jobs side by side on mid-size slices, so
    // completions, trace events, and bw-sharing joins interleave.
    for spec in specs() {
        let mut jobs = mix::hm4().jobs;
        jobs.insert(1, llm::qwen2_7b().job(11));
        jobs.insert(3, llm::flan_t5_infer().job(12));
        for seed in [21u64, 22] {
            lockstep(spec.clone(), 2, &jobs, seed % 2 == 0, seed);
        }
    }
}

#[test]
fn property_sweep_nonlegacy_power_models() {
    // The pluggable power models change the energy integrand but not
    // the event dynamics: both engines must stay in lockstep (event
    // sequence AND energy) under SliceProportional and Measured
    // attribution, exactly as they do under Legacy.
    use crate::power::{Calibration, PowerModel};
    for base in specs() {
        let cal = Calibration::default_for(&base);
        for model in [
            PowerModel::SliceProportional,
            PowerModel::Measured(cal.clone()),
        ] {
            let spec = Arc::new((*base).clone().with_power_model(model));
            for seed in [31u64, 32] {
                lockstep(spec.clone(), 0, &mix::hm2().jobs, false, seed);
                lockstep(
                    spec.clone(),
                    1,
                    &[llm::qwen2_7b().job(seed), llm::flan_t5_infer().job(seed + 1)],
                    true,
                    seed,
                );
            }
        }
    }
}

#[test]
fn flat_price_cost_integrals_agree_across_engines() {
    // $ = ∫ price·power dt must agree between the engines within the
    // same tolerance as the energy integral it is derived from.
    use crate::power::PriceSignal;
    let spec = Arc::new(GpuSpec::a100_40gb());
    let jobs = mix::hm2().jobs;
    let mut a = GpuSim::new(spec.clone(), false);
    let mut b = NaiveGpuSim::new(spec, false);
    a.set_price_signal(Some(PriceSignal::diurnal(0.08, 0.32, 10.0)));
    b.set_price_signal(Some(PriceSignal::diurnal(0.08, 0.32, 10.0)));
    let ia = a.mgr.alloc(2).unwrap();
    assert_eq!(b.mgr.alloc(2).unwrap(), ia);
    let mut backlog = jobs.clone();
    backlog.reverse();
    let first = backlog.pop().unwrap();
    a.launch(first.clone(), ia, 0.0);
    b.launch(first, ia, 0.0);
    loop {
        let (ea, eb) = (a.advance(), b.advance());
        match (ea, eb) {
            (None, None) => break,
            (Some(x), Some(y)) => {
                assert_events_equiv(&x, &y);
                if matches!(x, SimEvent::Finished { .. }) {
                    if let (Some(inst), Some(job)) = (ev_instance(&x), backlog.pop()) {
                        let t = a.now();
                        let id = a.launch(job.clone(), inst, t);
                        assert_eq!(id, b.launch(job, inst, t));
                    }
                }
            }
            (x, y) => panic!("priced run diverged: indexed {x:?} vs oracle {y:?}"),
        }
    }
    assert_close("priced energy", a.energy_j(), b.energy_j());
    assert_close("cost integral", a.cost_usd(), b.cost_usd());
    assert!(a.cost_usd() > 0.0);
}

#[test]
fn oom_relaunch_storm_churns_slots_identically() {
    // Heavy churn: a too-big static job (kmeans, 6GB true) OOMs the
    // moment its alloc lands on a 5GB slice and is relaunched in place,
    // 30 times per instance, before a fitting job finally runs to
    // completion. That is hundreds of insert/remove cycles through the
    // engines' job storage — every kill leaves stale calendar entries
    // behind and every relaunch reuses a freed slab slot — the storm
    // that used to stress the `HashMap` path and now pins the
    // generation-tag contract end-to-end, with both engines in
    // lockstep throughout.
    let spec = Arc::new(GpuSpec::a100_40gb());
    let mut a = GpuSim::new(spec.clone(), false);
    let mut b = NaiveGpuSim::new(spec, false);
    let mut insts = Vec::new();
    while let Ok(i) = a.mgr.alloc(0) {
        assert_eq!(b.mgr.alloc(0).unwrap(), i);
        insts.push(i);
    }
    let bad = crate::workloads::rodinia::by_name("kmeans").unwrap().job(7);
    let good = crate::workloads::rodinia::by_name("gaussian").unwrap().job(7);
    let mut remaining: HashMap<InstanceId, usize> = insts.iter().map(|&i| (i, 30)).collect();
    for &i in &insts {
        let id = a.launch(bad.clone(), i, 0.0);
        assert_eq!(id, b.launch(bad.clone(), i, 0.0));
    }
    let mut finished = 0usize;
    loop {
        let (ea, eb) = (a.advance(), b.advance());
        match (ea, eb) {
            (None, None) => break,
            (Some(x), Some(y)) => {
                assert_events_equiv(&x, &y);
                assert_close("storm clock", a.now(), b.now());
                match &x {
                    SimEvent::Oom { instance, .. } => {
                        let t = a.now();
                        let left = remaining.get_mut(instance).unwrap();
                        let next = if *left > 0 {
                            *left -= 1;
                            bad.clone()
                        } else {
                            good.clone()
                        };
                        let id = a.launch(next.clone(), *instance, t);
                        assert_eq!(id, b.launch(next, *instance, t));
                    }
                    SimEvent::Finished { .. } => finished += 1,
                    _ => {}
                }
            }
            (x, y) => panic!("storm presence diverged: indexed {x:?} vs oracle {y:?}"),
        }
    }
    // Every instance OOMed 31 times (initial launch + 30 relaunches)
    // then completed its fitting job exactly once.
    assert_eq!(finished, insts.len());
    assert_eq!(a.counters.oom_restarts, insts.len() * 31);
    assert_eq!(a.counters.oom_restarts, b.counters.oom_restarts);
    assert_eq!(a.records.len(), insts.len());
    assert_eq!(a.records.len(), b.records.len());
    assert_close("storm makespan", a.now(), b.now());
    assert_close("storm energy", a.energy_j(), b.energy_j());
}

#[test]
fn simultaneous_completions_identical_across_engines() {
    // Exact ties: identical jobs, identical launch instant. Both
    // engines must fire the co-due completions in ascending JobId
    // order (the oracle's run_order rule == the indexed tie-break).
    let spec = Arc::new(GpuSpec::a100_40gb());
    let job = crate::workloads::rodinia::by_name("gaussian").unwrap().job(7);
    let mut a = GpuSim::new(spec.clone(), false);
    let mut b = NaiveGpuSim::new(spec, false);
    for _ in 0..7 {
        let i = a.mgr.alloc(0).unwrap();
        assert_eq!(b.mgr.alloc(0).unwrap(), i);
        a.launch(job.clone(), i, 0.0);
        b.launch(job.clone(), i, 0.0);
    }
    let mut order_a = Vec::new();
    let mut order_b = Vec::new();
    while let Some(ev) = a.advance() {
        if let SimEvent::Finished { job, .. } = ev {
            order_a.push(job);
        }
    }
    while let Some(ev) = b.advance() {
        if let SimEvent::Finished { job, .. } = ev {
            order_b.push(job);
        }
    }
    assert_eq!(order_a, vec![0, 1, 2, 3, 4, 5, 6]);
    assert_eq!(order_a, order_b);
    assert_close("tie makespan", a.now(), b.now());
    assert_close("tie energy", a.energy_j(), b.energy_j());
}

#[test]
fn zero_length_horizon_windows_agree() {
    // Horizon == current clock: both engines must return None without
    // moving time, integrating energy, or firing events — repeatedly.
    let spec = Arc::new(GpuSpec::a100_40gb());
    let job = crate::workloads::rodinia::by_name("nw").unwrap().job(7);
    let mut a = GpuSim::new(spec.clone(), false);
    let mut b = NaiveGpuSim::new(spec, false);
    let i = a.mgr.alloc(0).unwrap();
    assert_eq!(b.mgr.alloc(0).unwrap(), i);
    a.launch(job.clone(), i, 0.0);
    b.launch(job, i, 0.0);
    let h = 0.02; // inside the alloc phase
    assert!(a.advance_with_horizon(Some(h)).is_none());
    assert!(b.advance_with_horizon(Some(h)).is_none());
    for _ in 0..4 {
        let (ta, ea) = (a.now(), a.energy_j());
        let (tb, eb) = (b.now(), b.energy_j());
        assert!(a.advance_with_horizon(Some(h)).is_none());
        assert!(b.advance_with_horizon(Some(h)).is_none());
        assert_eq!(a.now(), ta);
        assert_eq!(a.energy_j(), ea);
        assert_eq!(b.now(), tb);
        assert_eq!(b.energy_j(), eb);
    }
    while a.advance().is_some() {}
    while b.advance().is_some() {}
    assert_close("post-clip makespan", a.now(), b.now());
}
