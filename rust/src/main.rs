//! `migm` — the MIGM command-line launcher.
//!
//! ```text
//! migm run --mix ht2 --scheme a [--prediction] [--gpu a100] [--seed N]
//! migm run --config experiment.json
//! migm report <all|fig3|reach|prelim|fig4-rodinia|fig4-ml|fig4-llm|oom|online|seeds|table3|table4|power>
//! migm tune [--smoke] [--generator grid|random|halving] [--n 32] [--gpus 4]
//!           [--seed N] [--threads N] [--out FILE] [--trajectory FILE]
//! migm mig <list-configs|reachability> [--gpu a100]
//! migm serve --smoke [--requests N] [--seed N] [--slo-ms N] [--static N] [--json]
//! migm serve [--port 7700] [--replicas 2] [--variant decode_s128]   (pjrt builds)
//! migm client [--port 7700] --prompt 3,17,9 [--max-new 16]
//! ```

use std::net::TcpStream;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use migm::config::{ExperimentConfig, Scheme, DEFAULT_SEED};
use migm::metrics::fx;
use migm::mig::GpuSpec;
use migm::report;
use migm::scheduler;
#[cfg(feature = "pjrt")]
use migm::server::{serve, ServingConfig, ServingSystem};

/// Tiny flag parser: `--key value` and `--switch`.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let value = argv.get(i + 1).filter(|v| !v.starts_with("--")).cloned();
                if value.is_some() {
                    i += 1;
                }
                flags.push((name.to_string(), value));
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "run" => cmd_run(&args),
        "report" => cmd_report(&args),
        "tune" => cmd_tune(&args),
        "mig" => cmd_mig(&args),
        #[cfg(feature = "pjrt")]
        "serve" => {
            if args.has("smoke") || args.has("sim") || args.has("requests") {
                cmd_serve_sim(&args)
            } else {
                cmd_serve(&args)
            }
        }
        #[cfg(not(feature = "pjrt"))]
        "serve" => cmd_serve_sim(&args),
        "client" => cmd_client(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `migm help`)"),
    }
}

fn print_help() {
    println!(
        "migm — Multi-Instance GPU Manager (MIGM, CS.DC 2025 reproduction)

USAGE:
  migm run --mix <name> [--scheme baseline|a|b] [--prediction]
           [--gpu a100|a30|a100-80gb|h100] [--seed N] [--compare]
  migm run --config <file.json>
  migm report <all|fig3|reach|prelim|fig4-rodinia|fig4-ml|fig4-llm|oom|online|seeds|table3|table4|power>
  migm tune [--smoke] [--generator grid|random|halving] [--n 32] [--gpus 4]
            [--seed N] [--threads N] [--out FILE] [--trajectory FILE]
  migm mig <list-configs|reachability> [--gpu a100]
  migm serve --smoke [--requests N] [--seed N] [--slo-ms N] [--static N] [--json]
  migm serve [--port 7700] [--replicas 2] [--variant decode_s128]   (pjrt builds)
  migm client [--port 7700] --prompt 3,17,9 [--max-new 16]

Mixes: hm1-4, ht1-3, ml1-3, flan-t5-train, flan-t5, qwen2, llama3,
       preliminary-a30.

tune: policy-search sweep over scheduler + fleet-routing knobs on
      simulated fleets (incl. a mixed A30/A100/H100 heterogeneous
      scenario; knob axes cover placement engine, work stealing, and
      cost-model weights alongside the scheme knobs).
      Writes a schema-stable report (default BENCH_policy_search.json),
      optionally appends a summary row to a trajectory file, and (for
      grid runs) fails unless some candidate beats the default Scheme B
      knobs on at least one scenario.

report power: the same heterogeneous batch run uncapped, under a rack
      power cap (fleet governor: deferral, fission, parking; zero
      cap-violation seconds by construction), and capped+price-aware —
      comparing throughput, J/job, $/job over a shared price trace.

serve (simulated): continuous-batching LLM serving over a MIG fleet
      with SLO-driven autoscaling, driven by a deterministic engine
      over a compressed synthetic 24h diurnal trace. Reports sustained
      RPS at the p99 SLO, scale events, and J/request; byte-identical
      per seed. --static N provisions N fixed fast replicas with no
      autoscaler (the head-to-head baseline). In pjrt builds, `serve`
      without --smoke/--requests starts the live TCP front-end instead."
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(&PathBuf::from(path))?
    } else {
        let mix = args.get("mix").context("--mix (or --config) required")?;
        let scheme = Scheme::parse(args.get("scheme").unwrap_or("a"))?;
        let seed = args
            .get("seed")
            .map(|s| s.parse::<u64>())
            .transpose()?
            .unwrap_or(DEFAULT_SEED);
        ExperimentConfig::new(
            args.get("gpu").unwrap_or("a100"),
            mix,
            scheme,
            args.has("prediction"),
            seed,
        )?
    };
    let r = scheduler::run_experiment(&cfg);
    let m = &r.metrics;
    println!(
        "mix={} gpu={} scheme={} prediction={} seed={}",
        cfg.mix_name,
        cfg.gpu.name,
        cfg.scheme.name(),
        cfg.prediction,
        cfg.seed
    );
    println!(
        "jobs={} makespan={:.2}s throughput={:.3} j/s energy={:.0}J \
         energy/job={:.0}J mem-util={:.1}% turnaround={:.2}s reconf={} \
         reconf-windows={} reconf-s={:.1} oom={} early={}",
        m.n_jobs,
        m.makespan_s,
        m.throughput_jps,
        m.energy_j,
        m.energy_per_job_j,
        m.mem_utilization * 100.0,
        m.avg_turnaround_s,
        m.reconfig_ops,
        m.reconfig_windows,
        m.reconfig_time_s,
        m.oom_restarts,
        m.early_restarts
    );
    let l = &r.latency;
    println!(
        "latency: queue p50={:.2}s p99={:.2}s turnaround p50={:.2}s p99={:.2}s",
        l.p50_queue_s, l.p99_queue_s, l.p50_turnaround_s, l.p99_turnaround_s
    );
    if args.has("compare") && cfg.scheme != Scheme::Baseline {
        let base_cfg = ExperimentConfig {
            scheme: Scheme::Baseline,
            prediction: false,
            ..cfg.clone()
        };
        let b = scheduler::run_experiment(&base_cfg);
        let n = m.normalized_vs(&b.metrics);
        println!(
            "vs baseline: throughput {}  energy {}  mem-util {}  turnaround {}",
            fx(n.throughput),
            fx(n.energy),
            fx(n.mem_utilization),
            fx(n.turnaround)
        );
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let seed = args
        .get("seed")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(DEFAULT_SEED);
    let spec = GpuSpec::by_name(args.get("gpu").unwrap_or("a100")).context("gpu")?;
    let out = match what {
        "all" => report::all_reports(),
        "fig3" => report::fig3_configs(&spec).1.render(),
        "reach" => report::reachability_example(&spec).1.render(),
        "prelim" => report::preliminary_a30(seed).1.render(),
        "fig4-rodinia" => report::fig4_rodinia(seed).1.render(),
        "fig4-ml" => report::fig4_ml(seed).1.render(),
        "fig4-llm" => report::fig4_llm(seed).1.render(),
        "oom" => report::oom_case_study(seed).1.render(),
        "online" => {
            let rate: f64 = args
                .get("rate")
                .map(|s| s.parse())
                .transpose()?
                .unwrap_or(0.25);
            report::online_arrivals(seed, rate).1.render()
        }
        "seeds" => report::seed_sweep(&[1, 2, 3, 4, 5, 6]).render(),
        "table3" => report::table3_myocyte().1.render(),
        "table4" => report::table4_nw().1.render(),
        "power" => report::power_cap(seed).1.render(),
        other => bail!("unknown report '{other}'"),
    };
    println!("{out}");
    Ok(())
}

/// `migm tune` — run a policy-search sweep and gate on the result.
///
/// `--smoke` (or `MIGM_BENCH_SMOKE=1`) shrinks the space and fleet for
/// the CI perf-trajectory step. The sweep is deterministic per seed, so
/// the exit-code gate (grid runs must show some candidate beating the
/// default Scheme B knobs on at least one scenario) cannot flake.
fn cmd_tune(args: &Args) -> Result<()> {
    use migm::tuner::{sweep, Generator, ParamSpace, Scenario, SweepConfig};
    use migm::util::Json;

    let smoke = args.has("smoke") || std::env::var("MIGM_BENCH_SMOKE").is_ok();
    let seed = args
        .get("seed")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(DEFAULT_SEED);
    let threads = args
        .get("threads")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    let n_gpus = args
        .get("gpus")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(if smoke { 2 } else { 4 })
        .max(1);
    let n = args
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(32);
    let generator = match args.get("generator").unwrap_or("grid") {
        "grid" => Generator::Grid,
        "random" => Generator::Random { n },
        "halving" => Generator::Halving {
            n,
            eta: 3,
            finalists: 4,
            short_frac: 0.3,
        },
        other => bail!("unknown generator '{other}' (grid|random|halving)"),
    };
    let space = if smoke {
        ParamSpace::smoke()
    } else {
        ParamSpace::full()
    };
    let mut scenarios = vec![
        Scenario::synthetic_fleet(n_gpus, seed),
        Scenario::hetero_fleet(seed),
        Scenario::paper("ht2", seed).expect("known mix"),
    ];
    if !smoke {
        scenarios.push(Scenario::paper("ht3", seed).expect("known mix"));
        scenarios.push(Scenario::paper("ml1", seed).expect("known mix"));
        scenarios.push(Scenario::synthetic_fleet_online(n_gpus, seed, 2.0));
    }
    let cfg = SweepConfig {
        space,
        scenarios,
        generator,
        seed,
        threads,
    };
    let report = sweep(&cfg)?;
    println!("{}", report.render());

    let out = args.get("out").unwrap_or("BENCH_policy_search.json");
    std::fs::write(out, format!("{}\n", report.to_json()))
        .with_context(|| format!("writing sweep report {out}"))?;
    println!("wrote {out}");

    if let Some(path) = args.get("trajectory") {
        let text = match std::fs::read_to_string(path) {
            Ok(t) if !t.trim().is_empty() => t,
            _ => "[]".to_string(),
        };
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing trajectory file {path}: {e}"))?;
        let Json::Arr(mut rows) = doc else {
            bail!("trajectory file {path} must hold a JSON array");
        };
        rows.push(report.summary_json());
        std::fs::write(path, format!("{}\n", Json::Arr(rows)))
            .with_context(|| format!("writing trajectory {path}"))?;
        println!("appended summary to {path}");
    }

    // Perf gate (relative, deterministic per seed): some non-default
    // candidate must strictly beat the default-knob Scheme B reference
    // on at least one scenario — the structural knob advantage the
    // tiered synthetic fleet is built to expose. If a scheduler or
    // simulator change erases it, this exits non-zero. Absolute drift
    // (a uniformly slower simulator rescales reference and candidates
    // alike) is NOT gated here; it shows up in the trajectory rows'
    // absolute reference numbers instead.
    let best = report.best();
    if best.objective + 1e-9 < 1.0 {
        bail!(
            "perf gate: best candidate '{}' scores {:.4}, below the default Scheme B reference",
            best.candidate.label(),
            best.objective
        );
    }
    // Only nominal-load candidates count as knob wins: a candidate
    // whose arrival_scale lowers the offered load beats the (nominal
    // load) reference by changing the workload, not the policy.
    let knob_wins: Vec<&str> = report
        .ranked
        .iter()
        .filter(|c| !c.is_reference && (c.candidate.arrival_scale - 1.0).abs() < 1e-12)
        .flat_map(|c| c.outcomes.iter())
        .filter(|o| o.score > 1.0 + 1e-9)
        .map(|o| o.scenario.as_str())
        .collect();
    if knob_wins.is_empty() {
        // Only the exhaustive grid is guaranteed to contain the winning
        // knob point; random pools may miss it and halving may prune it
        // on a short horizon, so those runs just warn.
        if matches!(cfg.generator, Generator::Grid) {
            bail!(
                "perf gate: no candidate beats the default Scheme B knobs on any scenario \
                 (the knob advantage regressed)"
            );
        }
        println!("warning: no candidate beat the default Scheme B knobs in this pool");
    }
    println!(
        "perf gate OK: best '{}' objective {:.4}; default beaten on {} scenario run(s)",
        best.candidate.label(),
        best.objective,
        knob_wins.len()
    );
    Ok(())
}

fn cmd_mig(args: &Args) -> Result<()> {
    let spec = GpuSpec::by_name(args.get("gpu").unwrap_or("a100")).context("gpu")?;
    match args.positional.first().map(String::as_str) {
        Some("list-configs") => {
            let (rows, t) = report::fig3_configs(&spec);
            println!("{} fully-configured states on {}:", rows.len(), spec.name);
            println!("{}", t.render());
        }
        Some("reachability") => {
            println!("{}", report::reachability_example(&spec).1.render());
        }
        _ => bail!("usage: migm mig <list-configs|reachability>"),
    }
    Ok(())
}

/// `migm serve --smoke` / `--requests N` — the simulated serving
/// engine: diurnal traffic, continuous batching, SLO tracking, and
/// the autoscaler resizing replicas and MIG profiles. Available in
/// every build (no PJRT needed).
fn cmd_serve_sim(args: &Args) -> Result<()> {
    use migm::serving::{run, ServeConfig, SloTargets};
    let seed = args
        .get("seed")
        .map(|s| s.parse::<u64>())
        .transpose()?
        .unwrap_or(DEFAULT_SEED);
    let smoke = args.has("smoke");
    let n = args
        .get("requests")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(if smoke { 240 } else { 1000 });
    let mut cfg = if smoke && !args.has("requests") {
        ServeConfig::smoke(seed)
    } else {
        ServeConfig::diurnal(n, seed)
    };
    if let Some(ms) = args.get("slo-ms") {
        let p99: f64 = ms.parse()?;
        cfg.slo = SloTargets::new((p99 / 4.0).max(1.0), p99);
    }
    if let Some(k) = args.get("static") {
        cfg = cfg.static_fast(k.parse()?);
    }
    let r = run(&cfg);
    println!("{}", r.render());
    if args.has("json") {
        println!("{}", r.to_json());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    use std::net::TcpListener;
    use std::sync::Arc;
    let port: u16 = args.get("port").unwrap_or("7700").parse()?;
    let cfg = ServingConfig {
        replicas: args.get("replicas").unwrap_or("2").parse()?,
        variant: args.get("variant").unwrap_or("decode_s128").to_string(),
        ..Default::default()
    };
    let sys = Arc::new(ServingSystem::start(cfg)?);
    println!("replicas on slices: {:?}", sys.replica_slices);
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    println!("migm serving on 127.0.0.1:{port} (JSON lines; op=generate|stats|shutdown)");
    serve(listener, sys)?;
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let port: u16 = args.get("port").unwrap_or("7700").parse()?;
    let prompt = args.get("prompt").unwrap_or("1,2,3");
    let max_new: usize = args.get("max-new").unwrap_or("16").parse()?;
    let tokens: Vec<&str> = prompt.split(',').collect();
    let mut conn = TcpStream::connect(("127.0.0.1", port))?;
    writeln!(
        conn,
        r#"{{"op":"generate","prompt":[{}],"max_new":{}}}"#,
        tokens.join(","),
        max_new
    )?;
    let mut line = String::new();
    BufReader::new(conn).read_line(&mut line)?;
    println!("{}", line.trim());
    Ok(())
}
