//! Paper-figure harnesses: each function regenerates one table or
//! figure from the paper's evaluation (§1, §4, §5, Appendix A) and
//! returns both the raw numbers (for tests/benches) and a rendered
//! table (for the CLI and examples). Experiment ids follow the paper's
//! figure/table numbering.

use std::sync::Arc;

use crate::config::{Scheme, DEFAULT_SEED};
use crate::fleet::{FleetKnobs, FleetPolicy};
use crate::metrics::{fx, BatchMetrics, NormalizedMetrics, Table};
use crate::mig::{enumerate_states, GpuSpec, PartitionState, Placement, ReachabilityTable};
use crate::scheduler::{self, run_mix, Orchestrator, SchemeBKnobs};
use crate::workloads::mix::{self, LLM_MIXES, ML_MIXES, RODINIA_MIXES};
use crate::workloads::{llm, rodinia, ComputeModel};

/// E2 — Figure 3: all fully-configured MIG states of a GPU.
pub fn fig3_configs(spec: &GpuSpec) -> (Vec<String>, Table) {
    let (_, full) = enumerate_states(spec);
    let mut rows: Vec<String> = full.iter().map(|f| f.render(spec)).collect();
    rows.sort();
    let mut t = Table::new(&["#", "configuration"]);
    for (i, r) in rows.iter().enumerate() {
        t.row(vec![format!("{}", i + 1), r.clone()]);
    }
    (rows, t)
}

/// E3 — §4.2 worked example: reachability of each 1g placement from the
/// empty GPU.
pub fn reachability_example(spec: &GpuSpec) -> (Vec<(u8, u64)>, Table) {
    let table = ReachabilityTable::precompute(spec);
    let small = 0usize;
    let mut rows = Vec::new();
    for &start in &spec.profiles[small].placements.clone() {
        let s = PartitionState::from_placements(vec![Placement {
            profile: small as u8,
            start,
        }]);
        rows.push((start, table.fcr(&s).unwrap_or(0)));
    }
    let mut t = Table::new(&["placement", "future-configuration reachability"]);
    for (start, fcr) in &rows {
        t.row(vec![
            format!("{}@slice{}", spec.profiles[small].name, start),
            format!("{fcr}"),
        ]);
    }
    (rows, t)
}

/// One row of a Figure-4 style comparison.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    /// Workload mix name.
    pub mix: String,
    /// Scheduling scheme label.
    pub scheme: &'static str,
    /// Whether the run used time-series prediction.
    pub prediction: bool,
    /// Gains normalized to the sequential baseline.
    pub norm: NormalizedMetrics,
    /// The run's absolute metrics.
    pub metrics: BatchMetrics,
}

fn fig4_rows(
    spec: &Arc<GpuSpec>,
    mixes: &[&str],
    seed: u64,
    variants: &[(Scheme, bool, &'static str)],
) -> Vec<Fig4Row> {
    let mut rows = Vec::new();
    for name in mixes {
        let m = mix::by_name(name, seed).expect("known mix");
        let base = scheduler::baseline::run(spec.clone(), &m);
        for &(scheme, pred, label) in variants {
            let r = run_mix(spec.clone(), &m, scheme, pred);
            rows.push(Fig4Row {
                mix: m.name.to_string(),
                scheme: label,
                prediction: pred,
                norm: r.metrics.normalized_vs(&base.metrics),
                metrics: r.metrics,
            });
        }
    }
    rows
}

fn render_fig4(rows: &[Fig4Row]) -> Table {
    let mut t = Table::new(&[
        "mix",
        "scheme",
        "throughput",
        "energy",
        "mem-util",
        "turnaround",
        "reconf",
        "reconf-s",
        "reconf-lost",
        "oom",
        "early",
    ]);
    for r in rows {
        t.row(vec![
            r.mix.clone(),
            r.scheme.to_string(),
            fx(r.norm.throughput),
            fx(r.norm.energy),
            fx(r.norm.mem_utilization),
            fx(r.norm.turnaround),
            format!("{}", r.metrics.reconfig_ops),
            format!("{:.1}", r.metrics.reconfig_time_s),
            format!(
                "{:.1}%",
                100.0 * r.metrics.reconfig_time_s / r.metrics.makespan_s.max(1e-9)
            ),
            format!("{}", r.metrics.oom_restarts),
            format!("{}", r.metrics.early_restarts),
        ]);
    }
    t
}

/// E4 — Figures 4a–4d: the 7 Rodinia mixes under Scheme A and Scheme B,
/// normalized to the sequential baseline.
pub fn fig4_rodinia(seed: u64) -> (Vec<Fig4Row>, Table) {
    let spec = Arc::new(GpuSpec::a100_40gb());
    let rows = fig4_rows(
        &spec,
        &RODINIA_MIXES,
        seed,
        &[(Scheme::A, false, "A"), (Scheme::B, false, "B")],
    );
    let t = render_fig4(&rows);
    (rows, t)
}

/// E5 — Figures 4e–4h (DNN part): Ml1–Ml3.
pub fn fig4_ml(seed: u64) -> (Vec<Fig4Row>, Table) {
    let spec = Arc::new(GpuSpec::a100_40gb());
    let rows = fig4_rows(
        &spec,
        &ML_MIXES,
        seed,
        &[(Scheme::A, false, "A"), (Scheme::B, false, "B")],
    );
    let t = render_fig4(&rows);
    (rows, t)
}

/// E6 — Figures 4e–4h (dynamic part): the four LLM workloads under
/// Scheme A without prediction, Scheme A with prediction, and Scheme B
/// with prediction.
pub fn fig4_llm(seed: u64) -> (Vec<Fig4Row>, Table) {
    let spec = Arc::new(GpuSpec::a100_40gb());
    let rows = fig4_rows(
        &spec,
        &LLM_MIXES,
        seed,
        &[
            (Scheme::A, false, "A"),
            (Scheme::A, true, "A+pred"),
            (Scheme::B, true, "B+pred"),
        ],
    );
    let t = render_fig4(&rows);
    (rows, t)
}

/// E7/E8 — the OOM-prediction case study (paper §2.3 / §5.2.2): for each
/// dynamic workload, the iteration where OOM would strike on the start
/// slice, the iteration where the predictor converges, and the predicted
/// vs actual peak at 10% of iterations.
#[derive(Debug, Clone)]
pub struct OomCaseRow {
    /// Dynamic workload name.
    pub workload: String,
    /// Start-slice memory capacity, GB.
    pub cap_gb: f64,
    /// Iteration where OOM strikes on the start slice (None = fits).
    pub oom_iter: Option<usize>,
    /// Iteration where the predictor converges (None = never).
    pub predict_iter: Option<usize>,
    /// Converged peak projection, GB.
    pub predicted_peak_gb: f64,
    /// Peak projection using only the first 10% of iterations, GB.
    pub peak_at_10pct_gb: f64,
    /// Realized peak, GB.
    pub actual_peak_gb: f64,
    /// |projection at 10% − actual| / actual.
    pub err_at_10pct: f64,
}

/// Run the OOM-prediction case study (E7/E8) and render its table.
pub fn oom_case_study(seed: u64) -> (Vec<OomCaseRow>, Table) {
    use crate::predictor::{ConvergenceCfg, JobMonitor, PredictionOutcome};
    let spec = GpuSpec::a100_40gb();
    let mut rows = Vec::new();
    for w in llm::all() {
        let job = w.job(seed);
        let ComputeModel::Iterative(it) = &job.compute else {
            unreachable!()
        };
        let trace = it.trace.generate(it.trace_seed);
        // The start slice: smallest profile that survives iteration 0.
        let first_mem = trace.phys_gb[0];
        let start_prof = spec
            .profiles
            .iter()
            .filter(|p| p.mem_gb >= first_mem)
            .min_by(|a, b| a.mem_gb.partial_cmp(&b.mem_gb).unwrap())
            .unwrap();
        let cap = start_prof.mem_gb;
        let oom_iter = trace.oom_iter(cap);
        // online prediction
        let mut mon = JobMonitor::new(it.trace.n_iters, ConvergenceCfg::default());
        let mut predict_iter = None;
        let mut converged_peak = 0.0;
        for i in 0..trace.len() {
            if let PredictionOutcome::Converged { peak_physical_gb } =
                mon.push(trace.observation(i))
            {
                if peak_physical_gb > cap && predict_iter.is_none() {
                    predict_iter = Some(i);
                    converged_peak = peak_physical_gb;
                }
                if predict_iter.is_some() {
                    break;
                }
            }
        }
        // accuracy at 10% of iterations
        let n10 = (trace.len() / 10).max(ConvergenceCfg::default().min_obs);
        let mut mon10 = JobMonitor::new(it.trace.n_iters, ConvergenceCfg::default());
        for i in 0..n10 {
            mon10.push(trace.observation(i));
        }
        let peak10 = mon10
            .latest_fit()
            .map(|f| f.peak_physical_gb)
            .unwrap_or(0.0);
        let actual = trace.peak_gb();
        rows.push(OomCaseRow {
            workload: w.name.to_string(),
            cap_gb: cap,
            oom_iter,
            predict_iter,
            predicted_peak_gb: converged_peak,
            peak_at_10pct_gb: peak10,
            actual_peak_gb: actual,
            err_at_10pct: (peak10 - actual).abs() / actual,
        });
    }
    let mut t = Table::new(&[
        "workload",
        "slice",
        "OOM@iter",
        "predicted@iter",
        "pred peak",
        "peak@10%",
        "actual peak",
        "err@10%",
    ]);
    for r in &rows {
        t.row(vec![
            r.workload.clone(),
            format!("{:.0}GB", r.cap_gb),
            r.oom_iter.map_or("-".into(), |i| format!("{i}")),
            r.predict_iter.map_or("-".into(), |i| format!("{i}")),
            format!("{:.2}GB", r.predicted_peak_gb),
            format!("{:.2}GB", r.peak_at_10pct_gb),
            format!("{:.2}GB", r.actual_peak_gb),
            format!("{:.1}%", r.err_at_10pct * 100.0),
        ]);
    }
    (rows, t)
}

/// E9 — Table 3: myocyte phase breakdown on a 1g slice (7 live
/// instances) vs the full GPU.
pub fn table3_myocyte() -> ([(String, f64, f64); 5], Table) {
    let spec = GpuSpec::a100_40gb();
    let b = rodinia::by_name("myocyte").unwrap();
    let p = b.phases;
    let breakdown = |n_inst: f64, waves: f64| {
        let alloc = p.alloc_s * (1.0 + spec.alloc_overhead_per_instance * (n_inst - 1.0));
        let xfer_scale = 1.0 + 0.005 * (n_inst - 1.0);
        let h2d = p.h2d_pcie_s * xfer_scale;
        let kernel = p.steps as f64 * p.step_s * waves;
        let d2h = p.d2h_pcie_s * xfer_scale;
        let free = p.free_s + spec.free_overhead_per_instance_s * (n_inst - 1.0);
        [alloc, h2d, kernel, d2h, free]
    };
    let slice = breakdown(7.0, 1.0); // demand 1 on 1 GPC: 1 wave
    let full = breakdown(1.0, 1.0);
    let names = [
        "Allocate CPU/GPU Mem",
        "Read data and copy to GPU Mem",
        "GPU kernel runtime",
        "Copy data from GPU to CPU",
        "Free GPU Memory",
    ];
    let rows: [(String, f64, f64); 5] = std::array::from_fn(|i| {
        (names[i].to_string(), slice[i], full[i])
    });
    let mut t = Table::new(&["Metric", "Scheme A (7x1g.5gb)", "Baseline (Full GPU)"]);
    for (n, s, f) in &rows {
        t.row(vec![n.clone(), format!("{s:.4} s"), format!("{f:.4} s")]);
    }
    (rows, t)
}

/// E10 — Table 4: Needleman-Wunsch single-benchmark runtime, baseline
/// vs 7 concurrent 1g slices (PCIe contention), plus the batch-21
/// throughput factor the paper reports (~1.92x vs the 7x ceiling).
pub struct Table4Result {
    /// NW runtime alone on the full GPU, s.
    pub solo_runtime_s: f64,
    /// NW runtime with 7 concurrent copies on 1g slices, s.
    pub contended_runtime_s: f64,
    /// Batch-21 Scheme-A throughput over the baseline.
    pub batch21_throughput_x: f64,
}

/// Run the Table-4 NW PCIe-contention experiment and render its table.
pub fn table4_nw() -> (Table4Result, Table) {
    use crate::sim::{GpuSim, SimEvent};
    let spec = Arc::new(GpuSpec::a100_40gb());
    let job = rodinia::by_name("nw").unwrap().job(7);
    // solo on the full GPU
    let solo = job.baseline_runtime_s(7);
    // 7 concurrent on 1g slices
    let mut s = GpuSim::new(spec.clone(), false);
    for _ in 0..7 {
        let i = s.mgr.alloc(0).unwrap();
        s.launch(job.clone(), i, 0.0);
    }
    while s.advance().is_some() {}
    let contended = s.now();
    // batch of 21 under scheme A vs baseline
    let m = mix::Mix::batch("nw-x21", (0..21).map(|_| job.clone()).collect());
    let base = scheduler::baseline::run(spec.clone(), &m);
    let a = scheduler::scheme_a::run(spec.clone(), &m, false);
    let thr = a.metrics.throughput_jps / base.metrics.throughput_jps;
    let res = Table4Result {
        solo_runtime_s: solo,
        contended_runtime_s: contended,
        batch21_throughput_x: thr,
    };
    let mut t = Table::new(&["Metric", "Policy A (7x1g.5gb)", "Baseline (Full GPU)"]);
    t.row(vec![
        "Single Benchmark Runtime (s)".into(),
        format!("{contended:.3}"),
        format!("{solo:.3}"),
    ]);
    t.row(vec![
        "Batch-21 throughput vs baseline".into(),
        fx(thr),
        "1.00x".into(),
    ]);
    let _ = SimEvent::ReconfigDone; // (kind used elsewhere)
    (res, t)
}

/// E1 — §1 preliminary experiment on the A30: the same 14-job batch with
/// tightest-fit slices vs next-largest slices.
pub struct PreliminaryResult {
    /// Metrics with tightest-fit slice assignment.
    pub tight: BatchMetrics,
    /// Metrics with next-largest slice assignment.
    pub loose: BatchMetrics,
    /// Tight ÷ loose throughput.
    pub throughput_gain: f64,
    /// Loose ÷ tight energy (>1 means tight saves energy).
    pub energy_gain: f64,
}

/// Run the §1 A30 preliminary experiment and render its table.
pub fn preliminary_a30(seed: u64) -> (PreliminaryResult, Table) {
    let spec = Arc::new(GpuSpec::a30_24gb());
    let m = mix::preliminary_a30(seed);
    // tightest fit (the estimates as produced)
    let tight = scheduler::scheme_a::run(spec.clone(), &m, false);
    // next-largest: bump every estimate one class up
    let mut loose_mix = m.clone();
    for j in &mut loose_mix.jobs {
        let prof = spec.tightest_profile(j.est.point_gb(), 0).unwrap_or(0);
        if let Some(next) = spec.next_larger_profile(prof) {
            j.est = j.est.with_point(spec.profiles[next].mem_gb);
        }
    }
    let loose = scheduler::scheme_a::run(spec.clone(), &loose_mix, false);
    let res = PreliminaryResult {
        throughput_gain: tight.metrics.throughput_jps / loose.metrics.throughput_jps,
        energy_gain: loose.metrics.energy_j / tight.metrics.energy_j,
        tight: tight.metrics,
        loose: loose.metrics,
    };
    let mut t = Table::new(&["assignment", "throughput (j/s)", "energy (J)", "makespan (s)"]);
    t.row(vec![
        "tightest fit".into(),
        format!("{:.3}", res.tight.throughput_jps),
        format!("{:.0}", res.tight.energy_j),
        format!("{:.1}", res.tight.makespan_s),
    ]);
    t.row(vec![
        "next largest".into(),
        format!("{:.3}", res.loose.throughput_jps),
        format!("{:.0}", res.loose.energy_j),
        format!("{:.1}", res.loose.makespan_s),
    ]);
    t.row(vec![
        "improvement".into(),
        fx(res.throughput_gain),
        fx(res.energy_gain),
        String::new(),
    ]);
    (res, t)
}

/// Serving columns for the online report: the `migm serve` engine's
/// headline numbers. `None` on the batch/online policy rows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingCells {
    /// Requests completed within the p99 SLO, per second of trace.
    pub sustained_rps: f64,
    /// p99 headroom vs the SLO target, ms (negative = blown).
    pub slo_margin_ms: f64,
    /// Autoscaler scale-up decisions over the trace.
    pub scale_ups: usize,
    /// Autoscaler scale-down decisions over the trace.
    pub scale_downs: usize,
    /// Energy per completed request, J.
    pub j_per_request: f64,
}

impl ServingCells {
    /// Extract the headline cells from a full serve report.
    pub fn from_report(r: &crate::serving::ServeReport) -> ServingCells {
        ServingCells {
            sustained_rps: r.sustained_rps,
            slo_margin_ms: r.slo_margin_ms,
            scale_ups: r.scale_ups,
            scale_downs: r.scale_downs,
            j_per_request: r.j_per_request,
        }
    }
}

/// E11 — online arrivals: one row per policy over a Poisson arrival
/// stream, reporting throughput/energy plus the per-arrival latency
/// percentiles the batch experiments cannot express, and the belief
/// ledger's predicted-vs-actual peak-memory error.
#[derive(Debug, Clone)]
pub struct OnlineRow {
    /// Policy label.
    pub policy: &'static str,
    /// The run's absolute metrics.
    pub metrics: BatchMetrics,
    /// Per-arrival queueing/turnaround percentiles.
    pub latency: crate::metrics::LatencyStats,
    /// Predicted-vs-actual peak-memory accuracy (from the run's belief
    /// ledger; zero-valued for rows without prediction/dynamic jobs).
    pub prediction: crate::estimator::PredictionAccuracy,
    /// Per-GPU `(spec name, memory utilization)` in fleet order.
    /// Single-GPU rows carry exactly one entry (equal to
    /// `metrics.mem_utilization`).
    pub per_spec_util: Vec<(String, f64)>,
    /// Jobs the fleet router migrated off a backlogged shard (always 0
    /// for single-GPU rows and non-stealing policies).
    pub steals: u64,
    /// Serving-subsystem columns (the `serving-auto` row only).
    pub serving: Option<ServingCells>,
}

/// Rendered error cell: "-" until some prediction converged.
fn pred_err_cell(p: &crate::estimator::PredictionAccuracy) -> String {
    if p.n_predicted == 0 {
        "-".into()
    } else {
        format!("{:.1}%", p.mean_abs_pct_err * 100.0)
    }
}

fn render_online(rows: &[OnlineRow]) -> Table {
    let mut t = Table::new(&[
        "policy",
        "makespan (s)",
        "throughput (j/s)",
        "energy (J)",
        "reconf (n/s)",
        "queue p50/p99 (s)",
        "turnaround p50/p99 (s)",
        "per-spec util",
        "steals",
        "pred-err",
        "rps@slo",
        "slo-margin (ms)",
        "scale up/down",
        "J/req",
    ]);
    for r in rows {
        let util = r
            .per_spec_util
            .iter()
            .map(|(name, u)| format!("{name} {:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(", ");
        t.row(vec![
            r.policy.to_string(),
            format!("{:.1}", r.metrics.makespan_s),
            format!("{:.3}", r.metrics.throughput_jps),
            format!("{:.0}", r.metrics.energy_j),
            format!(
                "{} / {:.1}",
                r.metrics.reconfig_ops, r.metrics.reconfig_time_s
            ),
            format!("{:.2} / {:.2}", r.latency.p50_queue_s, r.latency.p99_queue_s),
            format!(
                "{:.2} / {:.2}",
                r.latency.p50_turnaround_s, r.latency.p99_turnaround_s
            ),
            util,
            r.steals.to_string(),
            pred_err_cell(&r.prediction),
            r.serving
                .map_or("-".into(), |s| format!("{:.2}", s.sustained_rps)),
            r.serving
                .map_or("-".into(), |s| format!("{:+.0}", s.slo_margin_ms)),
            r.serving
                .map_or("-".into(), |s| format!("{}/{}", s.scale_ups, s.scale_downs)),
            r.serving
                .map_or("-".into(), |s| format!("{:.1}", s.j_per_request)),
        ]);
    }
    t
}

/// Run four policies over the same Poisson-arrival stream — Ht2 plus
/// one dynamic (Qwen2) job so the predicted-vs-actual column is fed
/// end to end — at `rate_jps` jobs/second through the orchestrator.
/// The MIG schemes run with prediction enabled (the grow-on-demand
/// path: 5 GB → OOM → 10 GB → predictive restart → 20 GB); the
/// baseline's full GPU never restarts. The fourth row routes the same
/// stream across a mixed A30/A100/H100 fleet through
/// [`FleetPolicy`] with cost-model placement and work stealing
/// ([`FleetKnobs::balanced`]) over per-GPU Scheme B shards — the
/// per-spec utilization and steal columns come from it. Cost-model
/// placement is load-bearing, not a tuning choice: Ht2 carries a
/// 25 GB Full-class job that must never be dealt to the 24 GB A30.
pub fn online_arrivals(seed: u64, rate_jps: f64) -> (Vec<OnlineRow>, Table) {
    let spec = Arc::new(GpuSpec::a100_40gb());
    let mut m = mix::ht2(seed);
    m.jobs.push(llm::qwen2_7b().job(seed));
    let m = m.with_poisson_arrivals(rate_jps, seed);
    let mut rows = Vec::new();
    for (policy, scheme, pred) in [
        ("baseline", Scheme::Baseline, false),
        ("scheme-A", Scheme::A, true),
        ("scheme-B", Scheme::B, true),
    ] {
        let r = run_mix(spec.clone(), &m, scheme, pred);
        rows.push(OnlineRow {
            policy,
            per_spec_util: vec![(spec.name.clone(), r.metrics.mem_utilization)],
            steals: 0,
            metrics: r.metrics,
            latency: r.latency,
            prediction: r.prediction,
            serving: None,
        });
    }
    let fleet_specs = vec![
        Arc::new(GpuSpec::a30_24gb()),
        spec.clone(),
        Arc::new(GpuSpec::h100_80gb()),
    ];
    let policy = FleetPolicy::scheme_b(
        &fleet_specs,
        FleetKnobs::balanced(),
        SchemeBKnobs::default(),
    );
    let mut orch = Orchestrator::new(fleet_specs.clone(), true, policy);
    orch.submit_mix(&m);
    orch.run_to_completion();
    let r = orch.fleet_result();
    let per_spec_util = fleet_specs
        .iter()
        .enumerate()
        .map(|(g, s)| {
            let denom = (r.metrics.makespan_s * s.total_mem_gb).max(1e-12);
            (s.name.clone(), orch.gpu(g).mem_gb_integral() / denom)
        })
        .collect();
    rows.push(OnlineRow {
        policy: "fleet-B",
        per_spec_util,
        steals: orch.policy().steals(),
        metrics: r.metrics,
        latency: r.latency,
        prediction: r.prediction,
        serving: None,
    });
    // The fifth row is a different animal: the serving engine's
    // autoscaled smoke run (diurnal traffic, continuous batching,
    // SLO-driven scaling) projected onto the same table, with the
    // serving-only columns filled in.
    let sr = crate::serving::run(&crate::serving::ServeConfig::smoke(seed));
    rows.push(OnlineRow {
        policy: "serving-auto",
        per_spec_util: vec![(sr.gpu.clone(), sr.mem_utilization)],
        steals: 0,
        metrics: sr.as_batch_metrics(),
        latency: sr.latency,
        prediction: crate::estimator::PredictionAccuracy::default(),
        serving: Some(ServingCells::from_report(&sr)),
    });
    let t = render_online(&rows);
    (rows, t)
}

/// One arm of the power-cap comparison (E12).
#[derive(Debug, Clone)]
pub struct PowerArm {
    /// Arm label.
    pub label: &'static str,
    /// The run's absolute metrics.
    pub metrics: BatchMetrics,
    /// Electricity cost integral over the run, $.
    pub cost_usd: f64,
    /// Cost per completed job, $.
    pub usd_per_job: f64,
    /// Seconds the audited reserved draw spent above the cap — exactly
    /// 0 by construction on every governed arm (0 trivially when
    /// ungoverned).
    pub violation_s: f64,
    /// Peak reserved fleet draw the governor audited, W.
    pub peak_reserved_w: f64,
    /// Launches deferred because admission would breach the cap.
    pub deferrals: u64,
    /// Launches deferred into a cheaper price window.
    pub price_deferrals: u64,
    /// GPC-demand halvings triggered by repeated cap deferrals.
    pub fissions: u64,
    /// GPU-seconds spent parked at 0 W instead of the idle floor.
    pub parked_gpu_s: f64,
}

/// Run the heterogeneous fleet batch once under an optional governor
/// and price signal, collecting the power-side counters.
fn power_arm(
    specs: &[Arc<GpuSpec>],
    m: &mix::Mix,
    gov: Option<crate::power::PowerGovernor>,
    price: Option<crate::power::PriceSignal>,
    label: &'static str,
) -> PowerArm {
    let policy = FleetPolicy::scheme_b(specs, FleetKnobs::balanced(), SchemeBKnobs::default());
    let mut orch = Orchestrator::new(specs.to_vec(), false, policy);
    orch.set_power_governor(gov);
    orch.set_price_signal(price);
    orch.submit_mix(m);
    orch.run_to_completion();
    let r = orch.fleet_result();
    let cost_usd = orch.fleet_cost_usd();
    let (violation_s, peak_reserved_w, deferrals, price_deferrals, fissions, parked_gpu_s) =
        match orch.power_governor() {
            Some(g) => (
                g.violation_s(),
                g.peak_reserved_w(),
                g.deferrals(),
                g.price_deferrals(),
                g.fissions(),
                g.parked_gpu_s(),
            ),
            None => (0.0, 0.0, 0, 0, 0, 0.0),
        };
    PowerArm {
        label,
        usd_per_job: cost_usd / r.metrics.n_jobs.max(1) as f64,
        cost_usd,
        metrics: r.metrics,
        violation_s,
        peak_reserved_w,
        deferrals,
        price_deferrals,
        fissions,
        parked_gpu_s,
    }
}

/// E12 — the power story: the same heterogeneous Ht2 batch run three
/// ways — uncapped, under a rack-level
/// [`FleetPowerCap`](crate::power::FleetPowerCap), and capped with
/// price-aware deferral
/// over a two-step price trace that starts expensive and turns cheap
/// once the uncapped run would have drained. All three arms share one
/// price signal for $/job accounting; only the third acts on it. The
/// capped arms must report exactly zero cap-violation seconds, and the
/// price-aware arm wins on $/job by shifting (parked, 0 W) into the
/// cheap window.
pub fn power_cap(seed: u64) -> (Vec<PowerArm>, Table) {
    use crate::power::{FleetPowerCap, PowerGovernor, PriceSignal};
    let specs = vec![
        Arc::new(GpuSpec::a30_24gb()),
        Arc::new(GpuSpec::a100_40gb()),
        Arc::new(GpuSpec::h100_80gb()),
    ];
    let m = mix::ht2(seed);
    // Probe run fixes the price trace: expensive exactly as long as
    // the uncapped run takes, cheap after — so a price-blind run pays
    // peak rate throughout and a price-aware run can dodge all of it.
    let probe = power_arm(&specs, &m, None, None, "probe");
    let cheap_at = probe.metrics.makespan_s;
    let sig = PriceSignal::trace(vec![(0.0, 0.40), (cheap_at, 0.05)], cheap_at * 64.0);
    // Rack cap: every idle floor plus ~55% of the combined dynamic
    // range — any one GPU fits easily, the whole fleet flat-out does
    // not, so the governor has real work.
    let idle: f64 = specs.iter().map(|s| s.idle_power_w).sum();
    let range: f64 = specs.iter().map(|s| s.max_power_w - s.idle_power_w).sum();
    let cap_w = idle + 0.55 * range;
    let arms = vec![
        power_arm(&specs, &m, None, Some(sig.clone()), "uncapped"),
        power_arm(
            &specs,
            &m,
            Some(PowerGovernor::new(FleetPowerCap::new(cap_w)).with_price(sig.clone())),
            Some(sig.clone()),
            "capped",
        ),
        power_arm(
            &specs,
            &m,
            Some(
                PowerGovernor::new(FleetPowerCap::new(cap_w).with_price_deferral(0.15))
                    .with_price(sig.clone()),
            ),
            Some(sig),
            "capped+price-aware",
        ),
    ];
    let mut t = Table::new(&[
        "arm",
        "makespan (s)",
        "throughput (j/s)",
        "J/job",
        "$/job",
        "cap-viol (s)",
        "peak W",
        "defer cap/price",
        "fissions",
        "parked (gpu-s)",
    ]);
    for a in &arms {
        t.row(vec![
            a.label.to_string(),
            format!("{:.1}", a.metrics.makespan_s),
            format!("{:.3}", a.metrics.throughput_jps),
            format!("{:.0}", a.metrics.energy_per_job_j),
            format!("{:.4}", a.usd_per_job),
            format!("{:.1}", a.violation_s),
            format!("{:.0}", a.peak_reserved_w),
            format!("{}/{}", a.deferrals, a.price_deferrals),
            a.fissions.to_string(),
            format!("{:.0}", a.parked_gpu_s),
        ]);
    }
    (arms, t)
}

/// Seed-sensitivity sweep over the heterogeneous mixes: A-vs-B
/// throughput at each seed. The Ht1 ordering is draw-dependent;
/// Ht2/Ht3's grouping advantage is structural.
pub fn seed_sweep(seeds: &[u64]) -> Table {
    let spec = Arc::new(GpuSpec::a100_40gb());
    let mut t = Table::new(&["seed", "Ht1 A/B", "Ht2 A/B", "Ht3 A/B"]);
    for &seed in seeds {
        let mut cells = vec![format!("{seed}")];
        for name in ["Ht1", "Ht2", "Ht3"] {
            let m = mix::by_name(name, seed).unwrap();
            let base = scheduler::baseline::run(spec.clone(), &m);
            let a = run_mix(spec.clone(), &m, Scheme::A, false);
            let b = run_mix(spec.clone(), &m, Scheme::B, false);
            cells.push(format!(
                "{:.2} / {:.2}",
                a.metrics.throughput_jps / base.metrics.throughput_jps,
                b.metrics.throughput_jps / base.metrics.throughput_jps
            ));
        }
        t.row(cells);
    }
    t
}

/// Run every harness at the canonical seed (the `migm report all` path).
pub fn all_reports() -> String {
    let mut out = String::new();
    let spec = GpuSpec::a100_40gb();
    out.push_str("== E2: Figure 3 — valid A100 configurations ==\n");
    out.push_str(&fig3_configs(&spec).1.render());
    out.push_str("\n== E3: §4.2 reachability example ==\n");
    out.push_str(&reachability_example(&spec).1.render());
    out.push_str("\n== E1: §1 preliminary A30 experiment ==\n");
    out.push_str(&preliminary_a30(DEFAULT_SEED).1.render());
    out.push_str("\n== E4: Figures 4a-4d — Rodinia mixes ==\n");
    out.push_str(&fig4_rodinia(DEFAULT_SEED).1.render());
    out.push_str("\n== E5: Figures 4e-4h — DNN mixes ==\n");
    out.push_str(&fig4_ml(DEFAULT_SEED).1.render());
    out.push_str("\n== E6: Figures 4e-4h — dynamic LLM workloads ==\n");
    out.push_str(&fig4_llm(DEFAULT_SEED).1.render());
    out.push_str("\n== E7/E8: OOM prediction case study ==\n");
    out.push_str(&oom_case_study(DEFAULT_SEED).1.render());
    out.push_str("\n== E9: Table 3 — myocyte phase breakdown ==\n");
    out.push_str(&table3_myocyte().1.render());
    out.push_str("\n== E10: Table 4 — Needleman-Wunsch PCIe contention ==\n");
    out.push_str(&table4_nw().1.render());
    out.push_str("\n== E12: power cap — capped vs uncapped vs price-aware ==\n");
    out.push_str(&power_cap(DEFAULT_SEED).1.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_lists_19_rows() {
        let (rows, t) = fig3_configs(&GpuSpec::a100_40gb());
        assert_eq!(rows.len(), 19);
        assert_eq!(t.rows.len(), 19);
    }

    #[test]
    fn reachability_example_shape() {
        let (rows, _) = reachability_example(&GpuSpec::a100_40gb());
        assert_eq!(rows.len(), 7);
        let last = rows.last().unwrap().1;
        assert!(rows.iter().all(|&(_, f)| f <= last));
    }

    #[test]
    fn table3_shapes_match_paper() {
        let (rows, _) = table3_myocyte();
        // alloc: 0.24 -> ~0.96-0.98; d2h big on both; free grows ~40x
        assert!((rows[0].2 - 0.24).abs() < 1e-9);
        assert!((rows[0].1 - 0.96).abs() < 0.06, "{}", rows[0].1);
        assert!(rows[3].1 > 3.3 && rows[3].2 > 3.3);
        assert!(rows[4].1 / rows[4].2 > 20.0, "free overhead ratio");
    }

    #[test]
    fn table4_contention_factor_in_paper_range() {
        let (r, _) = table4_nw();
        let slowdown = r.contended_runtime_s / r.solo_runtime_s;
        // paper: 2.24x individual slowdown, 1.92x batch throughput
        assert!((1.5..3.2).contains(&slowdown), "slowdown {slowdown}");
        assert!((1.3..3.0).contains(&r.batch21_throughput_x), "thr {}", r.batch21_throughput_x);
    }

    #[test]
    fn preliminary_tight_beats_loose() {
        let (r, _) = preliminary_a30(DEFAULT_SEED);
        // paper: +20.6% throughput, +6.3% energy
        assert!(r.throughput_gain > 1.02, "thr {}", r.throughput_gain);
        assert!(r.energy_gain > 1.0, "energy {}", r.energy_gain);
    }

    #[test]
    fn power_report_caps_hold_and_price_awareness_wins_on_cost() {
        let (arms, t) = power_cap(DEFAULT_SEED);
        assert_eq!(arms.len(), 3);
        assert_eq!(t.rows.len(), 3);
        let (unc, cap, aware) = (&arms[0], &arms[1], &arms[2]);
        assert_eq!(unc.label, "uncapped");
        assert_eq!(cap.label, "capped");
        assert_eq!(aware.label, "capped+price-aware");
        // every arm completes the whole mix
        for a in &arms {
            assert_eq!(a.metrics.n_jobs, unc.metrics.n_jobs);
            assert_eq!(a.violation_s, 0.0, "{}: cap violations must be 0", a.label);
            assert!(a.cost_usd > 0.0, "{}: price signal attached", a.label);
        }
        // the cap bites (deferrals happen) but throughput loss is bounded
        assert!(cap.deferrals > 0, "cap must defer something");
        assert!(cap.metrics.makespan_s >= unc.metrics.makespan_s);
        assert!(cap.metrics.makespan_s <= 3.0 * unc.metrics.makespan_s);
        // price-aware shifts work into the cheap window and wins on $
        assert!(aware.price_deferrals > 0);
        assert!(aware.parked_gpu_s > 0.0);
        assert!(
            aware.usd_per_job < cap.usd_per_job,
            "price-aware ${} !< price-blind ${}",
            aware.usd_per_job,
            cap.usd_per_job
        );
        assert!(aware.usd_per_job < unc.usd_per_job);
    }

    #[test]
    fn online_report_covers_all_policies_with_latency() {
        let (rows, t) = online_arrivals(DEFAULT_SEED, 0.25);
        assert_eq!(rows.len(), 5);
        assert_eq!(t.rows.len(), 5);
        // the online report surfaces reconfiguration cost too
        assert!(t.header.contains(&"reconf (n/s)".to_string()));
        assert!(t.header.contains(&"pred-err".to_string()));
        assert!(t.header.contains(&"per-spec util".to_string()));
        assert!(t.header.contains(&"steals".to_string()));
        assert!(t.header.contains(&"rps@slo".to_string()));
        assert_eq!(rows[0].metrics.reconfig_time_s, 0.0, "baseline is zero-cost");
        assert!(rows[2].metrics.reconfig_time_s > 0.0, "scheme-B pays for windows");
        for r in &rows[..4] {
            assert_eq!(r.metrics.n_jobs, 19); // Ht2 + one dynamic job
        }
        for r in &rows {
            assert!(r.latency.p99_turnaround_s >= r.latency.p50_turnaround_s);
            assert!(r.latency.p99_queue_s >= r.latency.p50_queue_s);
        }
        // Single-GPU rows carry one utilization share and never steal;
        // the fleet row breaks utilization out per spec in fleet order.
        for r in &rows[..3] {
            assert_eq!(r.per_spec_util.len(), 1);
            assert_eq!(r.per_spec_util[0].0, "A100-40GB");
            assert_eq!(r.steals, 0);
        }
        let fleet = &rows[3];
        assert_eq!(fleet.policy, "fleet-B");
        let names: Vec<&str> = fleet.per_spec_util.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["A30-24GB", "A100-40GB", "H100-80GB"]);
        for (name, util) in &fleet.per_spec_util {
            assert!((0.0..=1.0).contains(util), "{name}: util {util}");
        }
        // The serving row rides along with its own columns: a real
        // autoscaled smoke run over the compressed diurnal day.
        let serve = &rows[4];
        assert_eq!(serve.policy, "serving-auto");
        assert_eq!(serve.metrics.n_jobs, 240);
        let cells = serve.serving.expect("serving row carries its cells");
        assert!(cells.sustained_rps > 0.0);
        assert!(cells.j_per_request > 0.0);
        assert!(rows[..4].iter().all(|r| r.serving.is_none()));
        // The dynamic job never converges a prediction on the baseline's
        // full GPU (nothing to outgrow); the MIG schemes — sharded or
        // fleet-routed — preempt it off the grow-on-demand slice and
        // report the ledger's error.
        assert_eq!(rows[0].prediction.n_predicted, 0);
        for r in &rows[1..4] {
            assert!(
                r.prediction.n_predicted >= 1,
                "{}: prediction should converge for the dynamic job",
                r.policy
            );
            assert!(
                r.prediction.mean_abs_pct_err < 0.5,
                "{}: error {}",
                r.policy,
                r.prediction.mean_abs_pct_err
            );
        }
        // Only the single-GPU scheme rows pin the early restart: on the
        // fleet the cost model may start the dynamic job on a GPU whose
        // post-OOM slice already covers the projected peak, making the
        // predictive restart legitimately unnecessary.
        for r in &rows[1..3] {
            assert!(r.metrics.early_restarts >= 1, "{}", r.policy);
        }
    }

    #[test]
    fn fig4_table_pins_reconfig_cost_fields() {
        // Pin the report surface: the fig4-style tables must carry the
        // reconfiguration-cost columns (op count, total seconds, and
        // the share of the makespan lost to windows), formatted as
        // rendered here.
        let metrics = BatchMetrics {
            n_jobs: 10,
            makespan_s: 50.0,
            throughput_jps: 0.2,
            energy_j: 1000.0,
            energy_per_job_j: 100.0,
            mem_utilization: 0.5,
            avg_turnaround_s: 25.0,
            reconfig_ops: 7,
            reconfig_windows: 3,
            reconfig_time_s: 0.7,
            oom_restarts: 1,
            early_restarts: 2,
        };
        let row = Fig4Row {
            mix: "Hm1".into(),
            scheme: "B",
            prediction: false,
            norm: metrics.normalized_vs(&metrics),
            metrics,
        };
        let t = render_fig4(&[row]);
        assert_eq!(
            t.header,
            vec![
                "mix",
                "scheme",
                "throughput",
                "energy",
                "mem-util",
                "turnaround",
                "reconf",
                "reconf-s",
                "reconf-lost",
                "oom",
                "early"
            ]
        );
        let cells = &t.rows[0];
        assert_eq!(cells[6], "7"); // reconfig ops
        assert_eq!(cells[7], "0.7"); // seconds in windows
        assert_eq!(cells[8], "1.4%"); // 0.7s of a 50s makespan
        assert_eq!(cells[9], "1");
        assert_eq!(cells[10], "2");
    }

    #[test]
    fn online_table_pins_prediction_error_field() {
        // Pin the report surface: the online table carries the belief
        // ledger's predicted-vs-actual peak-memory error column,
        // rendered as a percentage (or "-" before any convergence).
        use crate::estimator::PredictionAccuracy;
        use crate::metrics::LatencyStats;
        let metrics = BatchMetrics {
            n_jobs: 5,
            makespan_s: 100.0,
            throughput_jps: 0.05,
            energy_j: 5000.0,
            energy_per_job_j: 1000.0,
            mem_utilization: 0.4,
            avg_turnaround_s: 40.0,
            reconfig_ops: 2,
            reconfig_windows: 1,
            reconfig_time_s: 0.2,
            oom_restarts: 1,
            early_restarts: 1,
        };
        let with_pred = OnlineRow {
            policy: "scheme-B",
            metrics,
            latency: LatencyStats::default(),
            prediction: PredictionAccuracy {
                n_tracked: 1,
                n_predicted: 2,
                mean_abs_pct_err: 0.032,
            },
            per_spec_util: vec![("A30-24GB".into(), 0.25), ("H100-80GB".into(), 0.5)],
            steals: 3,
            serving: Some(ServingCells {
                sustained_rps: 4.25,
                slo_margin_ms: 250.0,
                scale_ups: 3,
                scale_downs: 2,
                j_per_request: 87.5,
            }),
        };
        let without = OnlineRow {
            policy: "baseline",
            prediction: PredictionAccuracy::default(),
            per_spec_util: vec![("A100-40GB".into(), 0.4)],
            steals: 0,
            serving: None,
            ..with_pred.clone()
        };
        let t = render_online(&[without, with_pred]);
        let n = t.header.len();
        // tail of the header: prediction error, then the four serving
        // columns the serve subsystem fills in.
        assert_eq!(
            &t.header[n - 5..],
            ["pred-err", "rps@slo", "slo-margin (ms)", "scale up/down", "J/req"]
        );
        assert_eq!(t.rows[0][n - 5], "-");
        assert_eq!(t.rows[1][n - 5], "3.2%");
        // serving cells render pinned: "-" everywhere without a report
        assert_eq!(&t.rows[0][n - 4..], ["-", "-", "-", "-"]);
        assert_eq!(&t.rows[1][n - 4..], ["4.25", "+250", "3/2", "87.5"]);
        // ...and the fleet columns, rendered one cell per spec.
        assert_eq!(t.rows[0][n - 7], "A100-40GB 40%");
        assert_eq!(t.rows[0][n - 6], "0");
        assert_eq!(t.rows[1][n - 7], "A30-24GB 25%, H100-80GB 50%");
        assert_eq!(t.rows[1][n - 6], "3");
    }

    #[test]
    fn oom_case_study_predicts_before_oom() {
        let (rows, _) = oom_case_study(DEFAULT_SEED);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            let oom = r.oom_iter.expect("every workload outgrows its start slice");
            let pred = r.predict_iter.expect("prediction must converge");
            assert!(pred < oom, "{}: pred {pred} !< oom {oom}", r.workload);
        }
        // average 10% error in the paper: 14.98%
        let avg = rows.iter().map(|r| r.err_at_10pct).sum::<f64>() / rows.len() as f64;
        assert!(avg < 0.35, "avg err {avg}");
    }
}
