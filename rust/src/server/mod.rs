//! LLM serving front-end: the online face of MIGM.
//!
//! A [`ServingSystem`] routes its GPU-facing bookkeeping through the
//! scheduling [`Orchestrator`]: replica slices are placed via
//! [`Orchestrator::reserve_instances`] — one atomic multi-create
//! `PartitionPlan` validated end-to-end, using the schedulers'
//! tightest-fit profile rule + the partition manager's
//! max-reachability allocator (shared mechanisms, not a policy event
//! loop; all-or-nothing by construction) — and every generation
//! request is submitted through the orchestrator's external-job
//! ledger, which yields the same queueing/turnaround percentile
//! accounting as the simulated online scenarios. The embedded FIFO
//! policy is inert today; it is the seam where simulated admission
//! control plugs in. One AOT [`DecodeEngine`] runs per
//! replica with continuous slot batching — the vLLM-router-shaped L3
//! of this stack. All engines live on a dedicated engine thread (PJRT
//! handles are not `Send`); a shortest-queue router feeds per-replica
//! slot maps; KV usage per replica is tracked and fed to the AOT
//! predictor so growth beyond the slice budget is flagged before it
//! happens.
//!
//! The TCP front speaks JSON-lines:
//!
//! ```text
//! -> {"op":"generate","prompt":[3,17,9],"max_new":16}
//! <- {"ok":true,"tokens":[...],"replica":0,"latency_ms":12.5}
//! -> {"op":"stats"}
//! <- {"ok":true,"requests":9,"tokens":144,...}
//! ```
//!
//! The *simulated* counterpart of this front-end lives in
//! [`serving`](crate::serving): same orchestrator seams
//! (`reserve_instances` / `release_instances` / `swap_instance`, the
//! external-job ledger, belief-band KV tracking), but driven by a
//! deterministic discrete-event engine with diurnal traffic, p50/p99
//! SLO tracking, and an autoscaler that resizes the replica fleet and
//! its MIG profiles. `migm serve --smoke` runs that engine; this
//! module is the live TCP path.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::estimator::{BeliefId, Estimate};
use crate::mig::GpuSpec;
use crate::predictor::Observation;
use crate::runtime::{DecodeEngine, Manifest, PjrtPredictor, Runtime};
use crate::scheduler::scheme_b::SchemeBPolicy;
use crate::scheduler::Orchestrator;
use crate::util::Json;

/// The serving stack's orchestrator flavor. Today the server uses the
/// orchestrator for replica placement and request-latency accounting
/// only; the FIFO (Scheme B) policy is carried inert as the seam for
/// future simulated admission control.
type ServerOrchestrator = Orchestrator<SchemeBPolicy>;

/// A generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Maximum tokens to generate.
    pub max_new: usize,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// Generated token ids.
    pub tokens: Vec<i32>,
    /// Replica that served the request.
    pub replica: usize,
    /// End-to-end latency, ms.
    pub latency_ms: f64,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    /// Requests completed.
    pub requests: u64,
    /// Tokens generated across all replicas.
    pub tokens_generated: u64,
    /// Batched decode steps executed.
    pub decode_steps: u64,
    /// Engine-thread wall time, s.
    pub elapsed_s: f64,
    /// Admissions paused by the KV confidence band.
    pub kv_alerts: u64,
    /// Per-replica generated-token counts.
    pub per_replica_tokens: Vec<u64>,
    /// Request queueing-delay percentiles (ms), from the orchestrator's
    /// external-job ledger.
    pub p50_queue_ms: f64,
    /// p99 queueing delay, ms.
    pub p99_queue_ms: f64,
    /// End-to-end request latency percentiles (ms).
    pub p50_latency_ms: f64,
    /// p99 end-to-end latency, ms.
    pub p99_latency_ms: f64,
}

impl ServingStats {
    /// Aggregate decode throughput, tokens/s.
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.elapsed_s.max(1e-9)
    }
}

enum Cmd {
    Generate(GenRequest, Sender<Result<GenResponse, String>>),
    Stats(Sender<ServingStats>),
    Shutdown,
}

/// Configuration of a serving system.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Directory holding `manifest.json` and the AOT artifacts.
    pub artifacts_dir: PathBuf,
    /// Decode variant to host (e.g. "decode_s128").
    pub variant: String,
    /// Replica count; each replica gets a tightest MIG slice.
    pub replicas: usize,
    /// GPU model replicas are carved from.
    pub gpu: GpuSpec,
    /// Seed for the deterministic random parameters.
    pub seed: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            artifacts_dir: Manifest::default_dir(),
            variant: "decode_s128".into(),
            replicas: 2,
            gpu: GpuSpec::a100_40gb(),
            seed: 7,
        }
    }
}

/// One request being decoded in a replica slot.
struct Slot {
    prompt: VecDeque<i32>,
    generated: Vec<i32>,
    max_new: usize,
    pos: i32,
    cur_token: i32,
    started: Instant,
    reply: Sender<Result<GenResponse, String>>,
    /// External-job token in the orchestrator's submission ledger.
    token: u64,
}

/// Engine-thread state for one replica.
struct Replica {
    engine: DecodeEngine,
    k: xla::Literal,
    v: xla::Literal,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(GenRequest, Sender<Result<GenResponse, String>>, u64)>,
    tokens_out: u64,
    /// This replica's KV-growth belief in the orchestrator's ledger:
    /// the per-step usage series, observed peak, and the predictor's
    /// refined band all live there (the same `MemoryBelief` machinery
    /// the simulated schedulers consult).
    belief: BeliefId,
    mem_budget_gb: f64,
}

/// Handle to a running serving system.
pub struct ServingSystem {
    tx: Sender<Cmd>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Human-readable placements ("1g.5gb@slice0") in replica order.
    pub replica_slices: Vec<String>,
}

impl ServingSystem {
    /// Start the engine thread: place replica slices through the
    /// scheduling orchestrator, load artifacts, and begin the decode
    /// loop.
    pub fn start(cfg: ServingConfig) -> Result<ServingSystem> {
        let spec = Arc::new(cfg.gpu.clone());
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let dm = manifest
            .decode
            .get(&cfg.variant)
            .with_context(|| format!("unknown decode variant {}", cfg.variant))?
            .clone();
        let need_gb = (dm.param_bytes + dm.kv_cache_bytes) as f64 / 1e9 + 0.5;
        // Replica placement goes through the orchestrator: the same
        // tightest-fit rule and max-reachability allocator the batch
        // policies use, instead of an ad-hoc manager loop.
        // Eager fit check (clean error + budget fallback for replicas=0).
        let prof = spec
            .tightest_profile(need_gb, 1)
            .context("model does not fit any MIG profile")?;
        let mut orch =
            ServerOrchestrator::single(spec.clone(), false, SchemeBPolicy::new(spec.clone()));
        let ids = orch
            .reserve_instances(0, need_gb, 1, cfg.replicas)
            .context("not enough MIG slices for replicas")?;
        // The KV-alert budget comes from the slice actually placed, so
        // it can never diverge from the reservation rule.
        let mem_budget_gb = ids
            .first()
            .and_then(|id| orch.gpu(0).mgr.mem_gb_of(*id))
            .unwrap_or(spec.profiles[prof].mem_gb);
        let mut slices = Vec::new();
        for id in &ids {
            let p = orch.gpu(0).mgr.placement_of(*id).unwrap();
            slices.push(format!(
                "{}@slice{}",
                spec.profiles[p.profile as usize].name, p.start
            ));
        }

        let (tx, rx) = channel::<Cmd>();
        let pm = manifest.predictor.values().next().cloned();
        let join = std::thread::spawn(move || {
            engine_thread(cfg, dm, pm, mem_budget_gb, rx, orch);
        });
        Ok(ServingSystem {
            tx,
            join: Some(join),
            replica_slices: slices,
        })
    }

    /// Submit one request and wait for the generation.
    pub fn generate(&self, req: GenRequest) -> Result<GenResponse> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::Generate(req, tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Snapshot the aggregate serving statistics.
    pub fn stats(&self) -> Result<ServingStats> {
        let (tx, rx) = channel();
        self.tx
            .send(Cmd::Stats(tx))
            .map_err(|_| anyhow::anyhow!("engine thread gone"))?;
        Ok(rx.recv()?)
    }

    /// Stop the engine thread and join it.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServingSystem {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_thread(
    cfg: ServingConfig,
    dm: crate::runtime::DecodeManifest,
    pm: Option<crate::runtime::PredictorManifest>,
    mem_budget_gb: f64,
    rx: Receiver<Cmd>,
    mut orch: ServerOrchestrator,
) {
    // PJRT handles are created on this thread and never leave it.
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("engine: PJRT init failed: {e:#}");
            return;
        }
    };
    let predictor = pm.and_then(|m| PjrtPredictor::new(&mut rt, &m).ok());
    let mut replicas: Vec<Replica> = Vec::new();
    for i in 0..cfg.replicas {
        let engine = match DecodeEngine::new(&mut rt, &dm, cfg.seed + i as u64) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("engine: replica {i} init failed: {e:#}");
                return;
            }
        };
        let (k, v) = engine.empty_kv().expect("kv alloc");
        let r = dm.batch;
        // KV growth is unknown upfront — exactly the time-series tier's
        // a-priori state; the PJRT predictor refines the belief online.
        let belief = orch.beliefs_mut().register(Estimate::unknown_upfront(1), 0.0);
        replicas.push(Replica {
            engine,
            k,
            v,
            slots: (0..r).map(|_| None).collect(),
            queue: VecDeque::new(),
            tokens_out: 0,
            belief,
            mem_budget_gb,
        });
    }

    let started = Instant::now();
    let mut stats = ServingStats {
        per_replica_tokens: vec![0; cfg.replicas],
        ..Default::default()
    };

    'outer: loop {
        // ---- ingest commands (non-blocking while work exists) ----
        let busy = replicas
            .iter()
            .any(|r| r.slots.iter().any(Option::is_some) || !r.queue.is_empty());
        loop {
            let cmd = if busy {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(_) => break,
                }
            } else {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => break 'outer,
                }
            };
            match cmd {
                Cmd::Generate(req, reply) => {
                    stats.requests += 1;
                    // submission enters the orchestrator's ledger (the
                    // queueing/turnaround accounting of online runs)
                    let token =
                        orch.submit_external("generate", started.elapsed().as_secs_f64());
                    // shortest-queue router
                    let (ri, _) = replicas
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, r)| {
                            r.queue.len() + r.slots.iter().filter(|s| s.is_some()).count()
                        })
                        .unwrap();
                    replicas[ri].queue.push_back((req, reply, token));
                    if !busy {
                        break;
                    }
                }
                Cmd::Stats(reply) => {
                    stats.elapsed_s = started.elapsed().as_secs_f64();
                    stats.per_replica_tokens =
                        replicas.iter().map(|r| r.tokens_out).collect();
                    let lat = orch.external_latency();
                    stats.p50_queue_ms = lat.p50_queue_s * 1e3;
                    stats.p99_queue_ms = lat.p99_queue_s * 1e3;
                    stats.p50_latency_ms = lat.p50_turnaround_s * 1e3;
                    stats.p99_latency_ms = lat.p99_turnaround_s * 1e3;
                    let _ = reply.send(stats.clone());
                }
                Cmd::Shutdown => break 'outer,
            }
        }

        // ---- one decode step per replica with active slots ----
        for (ri, rep) in replicas.iter_mut().enumerate() {
            // fill empty slots (continuous batching)
            for slot in rep.slots.iter_mut() {
                if slot.is_none() {
                    if let Some((req, reply, token)) = rep.queue.pop_front() {
                        orch.start_external(token, started.elapsed().as_secs_f64());
                        let mut prompt: VecDeque<i32> = req.prompt.iter().copied().collect();
                        let first = prompt.pop_front().unwrap_or(1).rem_euclid(
                            rep.engine.manifest.vocab as i32,
                        );
                        *slot = Some(Slot {
                            prompt,
                            generated: Vec::new(),
                            max_new: req.max_new,
                            pos: 0,
                            cur_token: first,
                            started: Instant::now(),
                            reply,
                            token,
                        });
                    }
                }
            }
            if rep.slots.iter().all(Option::is_none) {
                continue;
            }
            // build the batch (idle slots decode a dummy token at pos 0)
            let r = rep.slots.len();
            let mut tokens = vec![0i32; r];
            let mut pos = vec![0i32; r];
            for (i, s) in rep.slots.iter().enumerate() {
                if let Some(s) = s {
                    tokens[i] = s.cur_token;
                    pos[i] = s.pos;
                }
            }
            let out = match rep.engine.step_resident(&tokens, &pos, &rep.k, &rep.v) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("replica {ri}: step failed: {e:#}");
                    continue;
                }
            };
            rep.k = out.k_cache;
            rep.v = out.v_cache;
            stats.decode_steps += 1;
            // advance slots
            let max_seq = rep.engine.manifest.max_seq as i32;
            for (i, slot) in rep.slots.iter_mut().enumerate() {
                let Some(s) = slot.as_mut() else { continue };
                s.pos += 1;
                if let Some(next_prompt_tok) = s.prompt.pop_front() {
                    // prefill continues: feed the provided token
                    s.cur_token =
                        next_prompt_tok.rem_euclid(rep.engine.manifest.vocab as i32);
                } else {
                    // decode: consume the generated token
                    s.cur_token = out.next_tokens[i];
                    s.generated.push(out.next_tokens[i]);
                    rep.tokens_out += 1;
                    stats.tokens_generated += 1;
                }
                if s.generated.len() >= s.max_new || s.pos >= max_seq - 1 {
                    let done = slot.take().unwrap();
                    orch.complete_external(done.token, started.elapsed().as_secs_f64());
                    let _ = done.reply.send(Ok(GenResponse {
                        tokens: done.generated,
                        replica: ri,
                        latency_ms: done.started.elapsed().as_secs_f64() * 1e3,
                    }));
                }
            }
            // KV accounting -> belief ledger -> predictor alert (the
            // paper's early-resize signal on the real serving path,
            // routed through the same MemoryBelief machinery the
            // simulated schedulers consult)
            let used_gb = rep.engine.kv_bytes_used(&pos) as f64 / 1e9
                + rep.engine.manifest.param_bytes as f64 / 1e9;
            orch.beliefs_mut().observe_external(
                rep.belief,
                Observation {
                    req_mem_gb: used_gb,
                    reuse_ratio: 1.0,
                },
                used_gb,
            );
            if let Some(pred) = &predictor {
                let n = orch
                    .beliefs()
                    .get(rep.belief)
                    .external_series()
                    .map(|(m, _)| m.len())
                    .unwrap_or(0);
                if n >= 8 && n % 8 == 0 {
                    let (m, inv) = {
                        let (m, inv) = orch
                            .beliefs()
                            .get(rep.belief)
                            .external_series()
                            .expect("series just observed");
                        (m.to_vec(), inv.to_vec())
                    };
                    let horizon = (n * 4) as f64;
                    if let Ok(st) = pred.fit_batch(&[m], &[inv], &[horizon]) {
                        let demand = orch.beliefs_mut().apply_external_fit(rep.belief, &st[0]);
                        if demand > rep.mem_budget_gb {
                            stats.kv_alerts += 1;
                        }
                    }
                }
            }
        }
    }
    // Fail any queued work on shutdown.
    for rep in replicas {
        for (_, reply, _) in rep.queue {
            let _ = reply.send(Err("server shut down".into()));
        }
    }
}

/// Serve the JSON-lines protocol on `listener` until a shutdown op.
pub fn serve(listener: TcpListener, system: Arc<ServingSystem>) -> Result<()> {
    let stop = Arc::new(Mutex::new(false));
    for stream in listener.incoming() {
        if *stop.lock().unwrap() {
            break;
        }
        let stream = stream?;
        let sys = system.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let _ = handle_client(stream, sys, stop);
        });
    }
    Ok(())
}

fn handle_client(
    stream: TcpStream,
    sys: Arc<ServingSystem>,
    stop: Arc<Mutex<bool>>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let resp = match Json::parse(line.trim()) {
            Err(e) => Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(e.to_string()))]),
            Ok(doc) => match doc.get("op").as_str() {
                Some("generate") => {
                    let prompt: Vec<i32> = doc
                        .get("prompt")
                        .as_arr()
                        .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|v| v as i32).collect())
                        .unwrap_or_default();
                    let max_new = doc.get("max_new").as_u64().unwrap_or(16) as usize;
                    match sys.generate(GenRequest { prompt, max_new }) {
                        Ok(r) => Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            (
                                "tokens",
                                Json::Arr(r.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                            ),
                            ("replica", Json::num(r.replica as f64)),
                            ("latency_ms", Json::num(r.latency_ms)),
                        ]),
                        Err(e) => Json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", Json::str(format!("{e:#}"))),
                        ]),
                    }
                }
                Some("stats") => match sys.stats() {
                    Ok(s) => Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("requests", Json::num(s.requests as f64)),
                        ("tokens", Json::num(s.tokens_generated as f64)),
                        ("decode_steps", Json::num(s.decode_steps as f64)),
                        ("tokens_per_s", Json::num(s.tokens_per_s())),
                        ("kv_alerts", Json::num(s.kv_alerts as f64)),
                        ("p50_queue_ms", Json::num(s.p50_queue_ms)),
                        ("p99_queue_ms", Json::num(s.p99_queue_ms)),
                        ("p50_latency_ms", Json::num(s.p50_latency_ms)),
                        ("p99_latency_ms", Json::num(s.p99_latency_ms)),
                    ]),
                    Err(e) => Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str(format!("{e:#}"))),
                    ]),
                },
                Some("shutdown") => {
                    *stop.lock().unwrap() = true;
                    let r = Json::obj(vec![("ok", Json::Bool(true))]);
                    writeln!(out, "{r}")?;
                    return Ok(());
                }
                _ => Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str("unknown op")),
                ]),
            },
        };
        writeln!(out, "{resp}")?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn serving_system_generates_tokens() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let sys = ServingSystem::start(ServingConfig {
            replicas: 1,
            ..Default::default()
        })
        .unwrap();
        let r = sys
            .generate(GenRequest {
                prompt: vec![3, 17, 9],
                max_new: 8,
            })
            .unwrap();
        assert_eq!(r.tokens.len(), 8);
        let st = sys.stats().unwrap();
        assert_eq!(st.requests, 1);
        assert!(st.tokens_generated >= 8);
        // the orchestrator ledger recorded the request's latency
        assert!(st.p99_latency_ms > 0.0);
        assert!(st.p99_latency_ms >= st.p50_latency_ms);
        assert!(st.p99_queue_ms <= st.p99_latency_ms);
        sys.shutdown();
    }

    #[test]
    fn router_spreads_across_replicas() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let sys = Arc::new(
            ServingSystem::start(ServingConfig {
                replicas: 2,
                ..Default::default()
            })
            .unwrap(),
        );
        assert_eq!(sys.replica_slices.len(), 2);
        let mut handles = Vec::new();
        for i in 0..6 {
            let sys = sys.clone();
            handles.push(std::thread::spawn(move || {
                sys.generate(GenRequest {
                    prompt: vec![i as i32 + 1],
                    max_new: 4,
                })
                .unwrap()
            }));
        }
        let replicas: Vec<usize> = handles
            .into_iter()
            .map(|h| h.join().unwrap().replica)
            .collect();
        // both replicas should have served something
        assert!(replicas.iter().any(|&r| r == 0));
        assert!(replicas.iter().any(|&r| r == 1));
    }

    #[test]
    fn tcp_protocol_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let sys = Arc::new(
            ServingSystem::start(ServingConfig {
                replicas: 1,
                ..Default::default()
            })
            .unwrap(),
        );
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sys2 = sys.clone();
        std::thread::spawn(move || {
            let _ = serve(listener, sys2);
        });
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, r#"{{"op":"generate","prompt":[5,6],"max_new":3}}"#).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("ok").as_bool(), Some(true), "{line}");
        assert_eq!(doc.get("tokens").as_arr().unwrap().len(), 3);

        writeln!(conn, r#"{{"op":"stats"}}"#).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let doc = Json::parse(line.trim()).unwrap();
        assert_eq!(doc.get("ok").as_bool(), Some(true));
        assert!(doc.get("requests").as_f64().unwrap() >= 1.0);
    }
}
