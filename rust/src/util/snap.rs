//! Bit-exact scalar codecs for checkpoint snapshots.
//!
//! Snapshot JSON must round-trip every scalar *bit-identically*:
//! `restore(parse(to_string(snapshot(x))))` has to reproduce the exact
//! simulator state, and the resume difftest compares serialized
//! checkpoints byte-for-byte. [`Json`]'s `Display` is tuned for report
//! output — it prints integral floats through an `i64` shortcut (which
//! destroys the sign of `-0.0`) and has no representation at all for
//! NaN or the infinities. Finite non-special floats are safe: Rust's
//! `{}` float formatting is shortest-round-trip, so `to_string` →
//! `str::parse::<f64>` returns the same bits, and the integral
//! shortcut is exact for |n| < 2^53. The helpers here keep the common
//! case a plain `Json::Num` and spell the special cases as tagged
//! strings; `u64` counters always travel as decimal strings because
//! they can exceed the f64-exact integer range.

use anyhow::{bail, Context, Result};

use super::Json;

/// Encode an `f64` so it round-trips bit-exactly through JSON text.
pub fn f64_to_json(v: f64) -> Json {
    if v.is_nan() {
        Json::str("NaN")
    } else if v == f64::INFINITY {
        Json::str("inf")
    } else if v == f64::NEG_INFINITY {
        Json::str("-inf")
    } else if v == 0.0 && v.is_sign_negative() {
        Json::str("-0")
    } else {
        Json::Num(v)
    }
}

/// Decode an `f64` written by [`f64_to_json`].
pub fn f64_from_json(j: &Json) -> Result<f64> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => match s.as_str() {
            "NaN" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "-0" => Ok(-0.0),
            other => bail!("bad f64 snapshot literal {other:?}"),
        },
        other => bail!("expected f64 snapshot value, got {other}"),
    }
}

/// Encode a `u64` counter losslessly (always a decimal string:
/// `Json::Num` is an `f64` and would silently round above 2^53).
pub fn u64_to_json(v: u64) -> Json {
    Json::str(v.to_string())
}

/// Decode a `u64` written by [`u64_to_json`].
pub fn u64_from_json(j: &Json) -> Result<u64> {
    match j {
        Json::Str(s) => s.parse::<u64>().with_context(|| format!("bad u64 snapshot {s:?}")),
        other => bail!("expected u64 snapshot string, got {other}"),
    }
}

/// Encode a slice of `f64`s element-wise via [`f64_to_json`].
pub fn f64s_to_json(vs: &[f64]) -> Json {
    Json::Arr(vs.iter().map(|&v| f64_to_json(v)).collect())
}

/// Decode an array written by [`f64s_to_json`].
pub fn f64s_from_json(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()
        .context("expected f64 snapshot array")?
        .iter()
        .map(f64_from_json)
        .collect()
}

/// Typed `usize` accessor for snapshot fields (`Json::Num`-backed;
/// snapshot indices stay far below the f64-exact range).
pub fn usize_from_json(j: &Json) -> Result<usize> {
    match j {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
        other => bail!("expected usize snapshot value, got {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: f64) -> f64 {
        // Through *text*, exactly like a checkpoint file.
        let s = f64_to_json(v).to_string();
        f64_from_json(&Json::parse(&s).unwrap()).unwrap()
    }

    #[test]
    fn f64_text_roundtrip_is_bit_exact() {
        for v in [
            0.0,
            -0.0,
            1.0,
            -1.5,
            1e-300,
            -1e300,
            std::f64::consts::PI,
            f64::MIN_POSITIVE,
            f64::EPSILON,
            123456789.123456789,
            2.0_f64.powi(60),
            f64::INFINITY,
            f64::NEG_INFINITY,
        ] {
            assert_eq!(roundtrip(v).to_bits(), v.to_bits(), "{v} must round-trip");
        }
        assert!(roundtrip(f64::NAN).is_nan());
    }

    #[test]
    fn u64_text_roundtrip_is_exact_above_2_53() {
        for v in [0u64, 1, u64::MAX, (1u64 << 53) + 1, u64::MAX - 1] {
            let s = u64_to_json(v).to_string();
            assert_eq!(u64_from_json(&Json::parse(&s).unwrap()).unwrap(), v);
        }
    }

    #[test]
    fn f64s_roundtrip_and_reject_garbage() {
        let vs = vec![1.0, -0.0, f64::NAN, 2.5];
        let s = f64s_to_json(&vs).to_string();
        let back = f64s_from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back[0].to_bits(), 1.0f64.to_bits());
        assert_eq!(back[1].to_bits(), (-0.0f64).to_bits());
        assert!(back[2].is_nan());
        assert!(f64_from_json(&Json::Bool(true)).is_err());
        assert!(u64_from_json(&Json::num(3.0)).is_err());
        assert!(usize_from_json(&Json::num(1.5)).is_err());
        assert_eq!(usize_from_json(&Json::num(7.0)).unwrap(), 7);
    }
}
