//! Deterministic PRNG (xoshiro256++) + distributions.
//!
//! The offline build has no `rand` crate; this is a small, well-known
//! generator good enough for workload synthesis and property tests.
//! Seeding is explicit everywhere so every experiment is reproducible.

/// xoshiro256++ with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box-Muller.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; state is expanded from `seed` via splitmix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Next raw 64-bit output (xoshiro256++ step).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw: true with probability `p_true`.
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/σ.
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Exponential with rate `rate` (mean `1/rate`): the inter-arrival
    /// distribution of a Poisson process, used by the online arrival
    /// generators. Panics if `rate <= 0`.
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential rate must be positive");
        // f64() is in [0, 1), so 1 - u is in (0, 1] and ln is finite.
        -(1.0 - self.f64()).ln() / rate
    }

    /// Pick one element by reference.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(21);
        let n = 50_000;
        let rate = 2.5;
        let mean = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
        let mut r2 = Rng::new(21);
        for _ in 0..1000 {
            assert!(r2.exp(rate) >= 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(3) < 3);
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }
}
