//! Criterion-style micro-benchmark harness (offline build has no
//! criterion). Provides warmup, repeated timed runs, and robust summary
//! statistics printed in a stable, grep-friendly format:
//!
//! ```text
//! bench <name> ... median 12.3us  mean 12.5us  p95 13.0us  (n=200)
//! ```

use std::time::{Duration, Instant};

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One benchmark group, mirroring criterion's `Criterion` entrypoint.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 1_000_000,
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub n: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Faster settings for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly, print and return stats.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure individual iterations.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && (samples_ns.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(f64::total_cmp);
        let n = samples_ns.len() as u64;
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let pct = |p: f64| samples_ns[((p * (n as f64 - 1.0)) as usize).min(samples_ns.len() - 1)];
        let stats = BenchStats {
            name: name.to_string(),
            n,
            mean_ns: mean,
            median_ns: pct(0.5),
            p95_ns: pct(0.95),
            min_ns: samples_ns[0],
        };
        println!(
            "bench {:<44} median {:>10}  mean {:>10}  p95 {:>10}  (n={})",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            stats.n
        );
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_iters: 100_000,
        };
        let s = b.run("noop-ish", || (0..100u64).sum::<u64>());
        assert!(s.n > 10);
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn format_scales() {
        assert_eq!(fmt_ns(5.0), "5.0ns");
        assert_eq!(fmt_ns(5_000.0), "5.00us");
        assert_eq!(fmt_ns(5_000_000.0), "5.00ms");
        assert_eq!(fmt_ns(5e9), "5.000s");
    }
}
