//! Criterion-style micro-benchmark harness (offline build has no
//! criterion). Provides warmup, repeated timed runs, and robust summary
//! statistics printed in a stable, grep-friendly format:
//!
//! ```text
//! bench <name> ... median 12.3us  mean 12.5us  p95 13.0us  (n=200)
//! ```
//!
//! Also the one home of the `MIGM_BENCH_JSON` / `MIGM_TRAJECTORY`
//! artifact emitters the `benches/*.rs` binaries share
//! ([`write_bench_json_env`] / [`append_trajectory_rows_env`]), plus
//! [`validate_trajectory_row`], the schema gate every trajectory row
//! kind passes before it is appended.

use std::time::{Duration, Instant};

use super::Json;

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One benchmark group, mirroring criterion's `Criterion` entrypoint.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 1_000_000,
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark name as printed/serialized.
    pub name: String,
    /// Number of measured iterations.
    pub n: u64,
    /// Mean wall-clock time per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Median per-iteration time, nanoseconds.
    pub median_ns: f64,
    /// 95th-percentile per-iteration time, nanoseconds.
    pub p95_ns: f64,
    /// Fastest observed iteration, nanoseconds.
    pub min_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

impl Bench {
    /// Default settings (200ms warmup, 800ms measurement window).
    pub fn new() -> Self {
        Self::default()
    }

    /// Faster settings for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(400),
            max_iters: 10_000,
        }
    }

    /// Run `f` repeatedly, print and return stats.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure individual iterations.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && (samples_ns.len() as u64) < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(f64::total_cmp);
        let n = samples_ns.len() as u64;
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let pct = |p: f64| samples_ns[((p * (n as f64 - 1.0)) as usize).min(samples_ns.len() - 1)];
        let stats = BenchStats {
            name: name.to_string(),
            n,
            mean_ns: mean,
            median_ns: pct(0.5),
            p95_ns: pct(0.95),
            min_ns: samples_ns[0],
        };
        println!(
            "bench {:<44} median {:>10}  mean {:>10}  p95 {:>10}  (n={})",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            stats.n
        );
        stats
    }
}

/// The per-run stats document every bench writes under
/// `MIGM_BENCH_JSON` (`{schema, smoke, results: [...]}`).
pub fn bench_json_doc(schema: &str, smoke: bool, stats: &[BenchStats]) -> Json {
    let results: Vec<Json> = stats
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("name", Json::str(s.name.clone())),
                ("n", Json::num(s.n as f64)),
                ("median_ns", Json::num(s.median_ns)),
                ("mean_ns", Json::num(s.mean_ns)),
                ("p95_ns", Json::num(s.p95_ns)),
                ("min_ns", Json::num(s.min_ns)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::str(schema)),
        ("smoke", Json::Bool(smoke)),
        ("results", Json::Arr(results)),
    ])
}

/// If `MIGM_BENCH_JSON=<path>` is set, write the stats document there
/// (the CI perf artifact) — the shared tail of every bench binary.
pub fn write_bench_json_env(schema: &str, smoke: bool, stats: &[BenchStats]) {
    if let Ok(path) = std::env::var("MIGM_BENCH_JSON") {
        let doc = bench_json_doc(schema, smoke, stats);
        std::fs::write(&path, format!("{doc}\n")).expect("writing bench JSON");
        println!("wrote {path}");
    }
}

/// If `MIGM_TRAJECTORY=<path>` is set, append `rows` to the flat JSON
/// array there (missing/empty/corrupt file ⇒ start fresh), preserving
/// the trailing newline. Every row must pass
/// [`validate_trajectory_row`] — a bench cannot append a row shape the
/// trajectory consumers don't know.
pub fn append_trajectory_rows_env(rows: &[Json]) {
    let Ok(path) = std::env::var("MIGM_TRAJECTORY") else {
        return;
    };
    for row in rows {
        if let Err(e) = validate_trajectory_row(row) {
            panic!("refusing to append malformed trajectory row: {e}");
        }
    }
    let text = match std::fs::read_to_string(&path) {
        Ok(t) if !t.trim().is_empty() => t,
        _ => "[]".to_string(),
    };
    let all = match Json::parse(&text) {
        Ok(Json::Arr(mut existing)) => {
            existing.extend(rows.iter().cloned());
            existing
        }
        _ => rows.to_vec(),
    };
    std::fs::write(&path, format!("{}\n", Json::Arr(all))).expect("writing trajectory");
    println!("appended {} trajectory row(s) to {path}", rows.len());
}

/// Schema tag of [`speedup_bench_row`]; bump on any shape change.
pub const SPEEDUP_ROW_SCHEMA: &str = "migm.bench.speedup.v1";

/// Build the generic A-vs-B timing row (`migm.bench.speedup.v1`): one
/// baseline arm, one contender arm, and their wall-clock ratio. Used by
/// `benches/des_engine.rs` (naive vs indexed engine) and
/// `benches/orchestrator_fleet.rs` (sequential vs parallel
/// advancement); `n_jobs`/`n_gpus` record the scenario scale.
pub fn speedup_bench_row(
    bench: &str,
    n_jobs: usize,
    n_gpus: usize,
    baseline: (&str, f64),
    contender: (&str, f64),
) -> Json {
    let arm = |(label, elapsed_ns): (&str, f64)| {
        Json::obj(vec![
            ("label", Json::str(label)),
            ("elapsed_ns", Json::num(elapsed_ns)),
        ])
    };
    Json::obj(vec![
        ("schema", Json::str(SPEEDUP_ROW_SCHEMA)),
        ("bench", Json::str(bench)),
        ("n_jobs", Json::num(n_jobs as f64)),
        ("n_gpus", Json::num(n_gpus as f64)),
        ("speedup", Json::num(baseline.1 / contender.1.max(1.0))),
        ("baseline", arm(baseline)),
        ("contender", arm(contender)),
    ])
}

/// Schema tag of [`reachability_bench_row`]; bump on any shape change.
pub const REACHABILITY_ROW_SCHEMA: &str = "migm.bench.reachability.v1";

/// Build the reachability-scaling row (`migm.bench.reachability.v1`):
/// how long one spec's table takes to precompute and answer an `fcr`
/// query, with the spec's width and whether the analytic (non-
/// enumerating) path handled it. `full_configs` saturates at
/// `u64::MAX`, so it crosses JSON as a string via
/// [`snap::u64_to_json`](crate::util::snap::u64_to_json).
pub fn reachability_bench_row(
    bench: &str,
    spec: &str,
    n_mem_slices: usize,
    analytic: bool,
    full_configs: u64,
    precompute_ns: f64,
    fcr_query_ns: f64,
) -> Json {
    Json::obj(vec![
        ("schema", Json::str(REACHABILITY_ROW_SCHEMA)),
        ("bench", Json::str(bench)),
        ("spec", Json::str(spec)),
        ("n_mem_slices", Json::num(n_mem_slices as f64)),
        ("analytic", Json::Bool(analytic)),
        ("full_configs", crate::util::snap::u64_to_json(full_configs)),
        ("precompute_ns", Json::num(precompute_ns)),
        ("fcr_query_ns", Json::num(fcr_query_ns)),
    ])
}

/// Schema tag of [`power_bench_row`]; bump on any shape change.
pub const POWER_ROW_SCHEMA: &str = "migm.bench.power.v1";

/// One arm of the power-cap bench row: the headline economics of a
/// single governed (or ungoverned) fleet run.
#[derive(Debug, Clone, Copy)]
pub struct PowerBenchArm<'a> {
    /// Arm label ("uncapped" / "capped" / "capped+price-aware").
    pub label: &'a str,
    /// Batch makespan, s.
    pub makespan_s: f64,
    /// Throughput, jobs/s.
    pub throughput_jps: f64,
    /// Energy per job, J.
    pub energy_per_job_j: f64,
    /// Electricity cost per job, $.
    pub usd_per_job: f64,
    /// Seconds the audited reserved draw spent above the cap.
    pub violation_s: f64,
    /// Cap deferrals.
    pub deferrals: u64,
    /// Price deferrals.
    pub price_deferrals: u64,
    /// GPU-seconds parked at 0 W.
    pub parked_gpu_s: f64,
}

impl PowerBenchArm<'_> {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("throughput_jps", Json::num(self.throughput_jps)),
            ("energy_per_job_j", Json::num(self.energy_per_job_j)),
            ("usd_per_job", Json::num(self.usd_per_job)),
            ("violation_s", Json::num(self.violation_s)),
            ("deferrals", Json::num(self.deferrals as f64)),
            ("price_deferrals", Json::num(self.price_deferrals as f64)),
            ("parked_gpu_s", Json::num(self.parked_gpu_s)),
        ])
    }
}

/// Build the power-cap head-to-head row (`migm.bench.power.v1`): the
/// same fleet batch uncapped, capped, and capped+price-aware over a
/// shared price signal. `throughput_retention` is capped ÷ uncapped
/// throughput (1.0 = the cap cost nothing); `usd_per_job_ratio` is
/// price-blind ÷ price-aware $/job, so **> 1.0 means price awareness
/// wins**. The validator rejects rows whose governed arms report any
/// cap-violation seconds — zero is the governor's construction
/// invariant, not a tuning outcome.
pub fn power_bench_row(
    bench: &str,
    n_jobs: usize,
    cap_w: f64,
    uncapped: PowerBenchArm,
    capped: PowerBenchArm,
    price_aware: PowerBenchArm,
) -> Json {
    Json::obj(vec![
        ("schema", Json::str(POWER_ROW_SCHEMA)),
        ("bench", Json::str(bench)),
        ("n_jobs", Json::num(n_jobs as f64)),
        ("cap_w", Json::num(cap_w)),
        (
            "throughput_retention",
            Json::num(capped.throughput_jps / uncapped.throughput_jps.max(1e-12)),
        ),
        (
            "usd_per_job_ratio",
            Json::num(capped.usd_per_job / price_aware.usd_per_job.max(1e-12)),
        ),
        ("uncapped", uncapped.to_json()),
        ("capped", capped.to_json()),
        ("price_aware", price_aware.to_json()),
    ])
}

fn require_keys(row: &Json, ctx: &str, keys: &[&str]) -> Result<(), String> {
    for k in keys {
        if row.get(k).is_null() {
            return Err(format!("{ctx} missing key '{k}'"));
        }
    }
    Ok(())
}

/// Structural validation of one perf-trajectory row, dispatched on its
/// `schema` tag. Covers every bench-emitted row kind; the sweep summary
/// rows (`migm.policy_search.summary.*`) are emitted by `migm tune`
/// itself and pass through untouched.
pub fn validate_trajectory_row(row: &Json) -> Result<(), String> {
    let schema = row
        .get("schema")
        .as_str()
        .ok_or_else(|| "row has no schema tag".to_string())?;
    match schema {
        "migm.bench.fleet.v1" => {
            require_keys(
                row,
                schema,
                &[
                    "bench",
                    "n_jobs",
                    "fleet",
                    "sharded",
                    "makespan_speedup",
                    "energy_per_job_ratio",
                ],
            )?;
            for arm in ["fleet", "sharded"] {
                require_keys(
                    row.get(arm),
                    &format!("{schema}.{arm}"),
                    &[
                        "makespan_s",
                        "throughput_jps",
                        "energy_per_job_j",
                        "p99_turnaround_s",
                    ],
                )?;
            }
            Ok(())
        }
        "migm.bench.serving.v1" => {
            require_keys(
                row,
                schema,
                &[
                    "bench",
                    "n_requests",
                    "autoscaled",
                    "static",
                    "rps_at_slo_ratio",
                    "j_per_request_ratio",
                ],
            )?;
            for arm in ["autoscaled", "static"] {
                require_keys(
                    row.get(arm),
                    &format!("{schema}.{arm}"),
                    &[
                        "label",
                        "sustained_rps",
                        "within_slo",
                        "p99_turnaround_s",
                        "slo_margin_ms",
                        "energy_j",
                        "j_per_request",
                        "scale_ups",
                        "scale_downs",
                    ],
                )?;
            }
            Ok(())
        }
        "migm.bench.warmstart.v1" => {
            require_keys(
                row,
                schema,
                &[
                    "bench",
                    "n_candidates",
                    "warm",
                    "cold",
                    "from_zero_ratio",
                    "speedup",
                    "report_bytes_identical",
                ],
            )?;
            for arm in ["warm", "cold"] {
                require_keys(
                    row.get(arm),
                    &format!("{schema}.{arm}"),
                    &["elapsed_ns", "from_zero", "resumed", "reused"],
                )?;
            }
            if row.get("report_bytes_identical").as_bool() != Some(true) {
                return Err(format!(
                    "{schema}: report_bytes_identical must be true — the warm path may \
                     not change sweep results"
                ));
            }
            Ok(())
        }
        "migm.bench.fault.v1" => require_keys(
            row,
            schema,
            &[
                "bench",
                "timeline",
                "requeued_jobs",
                "steals",
                "n_completed",
                "makespan_s",
                "energy_j",
                "p99_turnaround_s",
            ],
        ),
        "migm.bench.speedup.v1" => {
            require_keys(
                row,
                schema,
                &["bench", "n_jobs", "n_gpus", "speedup", "baseline", "contender"],
            )?;
            for arm in ["baseline", "contender"] {
                require_keys(
                    row.get(arm),
                    &format!("{schema}.{arm}"),
                    &["label", "elapsed_ns"],
                )?;
            }
            Ok(())
        }
        "migm.bench.power.v1" => {
            require_keys(
                row,
                schema,
                &[
                    "bench",
                    "n_jobs",
                    "cap_w",
                    "throughput_retention",
                    "usd_per_job_ratio",
                    "uncapped",
                    "capped",
                    "price_aware",
                ],
            )?;
            for arm in ["uncapped", "capped", "price_aware"] {
                require_keys(
                    row.get(arm),
                    &format!("{schema}.{arm}"),
                    &[
                        "label",
                        "makespan_s",
                        "throughput_jps",
                        "energy_per_job_j",
                        "usd_per_job",
                        "violation_s",
                        "deferrals",
                        "price_deferrals",
                        "parked_gpu_s",
                    ],
                )?;
            }
            for arm in ["capped", "price_aware"] {
                if row.get(arm).get("violation_s").as_f64() != Some(0.0) {
                    return Err(format!(
                        "{schema}.{arm}: violation_s must be exactly 0 — the governor \
                         holds the cap by construction"
                    ));
                }
            }
            Ok(())
        }
        "migm.bench.reachability.v1" => require_keys(
            row,
            schema,
            &[
                "bench",
                "spec",
                "n_mem_slices",
                "analytic",
                "full_configs",
                "precompute_ns",
                "fcr_query_ns",
            ],
        ),
        other => Err(format!("unknown trajectory row schema '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_iters: 100_000,
        };
        let s = b.run("noop-ish", || (0..100u64).sum::<u64>());
        assert!(s.n > 10);
        assert!(s.median_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn format_scales() {
        assert_eq!(fmt_ns(5.0), "5.0ns");
        assert_eq!(fmt_ns(5_000.0), "5.00us");
        assert_eq!(fmt_ns(5_000_000.0), "5.00ms");
        assert_eq!(fmt_ns(5e9), "5.000s");
    }

    #[test]
    fn bench_json_doc_shape_is_pinned() {
        let stats = vec![BenchStats {
            name: "x".into(),
            n: 3,
            mean_ns: 2.0,
            median_ns: 2.0,
            p95_ns: 3.0,
            min_ns: 1.0,
        }];
        let doc = bench_json_doc("migm.bench.test_suite.v1", true, &stats);
        assert_eq!(doc.get("schema").as_str(), Some("migm.bench.test_suite.v1"));
        assert_eq!(doc.get("smoke").as_bool(), Some(true));
        let r = doc.get("results").at(0);
        for key in ["name", "n", "median_ns", "mean_ns", "p95_ns", "min_ns"] {
            assert!(!r.get(key).is_null(), "result missing '{key}'");
        }
        assert_eq!(Json::parse(&doc.to_string()).unwrap(), doc);
    }

    /// Every trajectory row kind, built from the REAL builders, must
    /// pass the validator — so a builder shape change and the
    /// validator can't drift apart silently.
    #[test]
    fn validator_accepts_every_real_row_kind() {
        use crate::serving::{run, serving_bench_row, ServeConfig};
        use crate::tuner::{fleet_bench_row, warmstart_bench_row, FleetBenchArm, WarmstartArm};

        let arm = FleetBenchArm {
            makespan_s: 10.0,
            throughput_jps: 2.0,
            energy_per_job_j: 40.0,
            p99_turnaround_s: 8.0,
        };
        let fleet = fleet_bench_row("orch_hetero_fleet_vs_sharded", 120, arm, arm);
        validate_trajectory_row(&fleet).expect("fleet row must validate");

        let r = run(&ServeConfig::smoke(7));
        let serving = serving_bench_row("serve_smoke", r.n_requests, &r, &r);
        validate_trajectory_row(&serving).expect("serving row must validate");

        let warm = WarmstartArm {
            elapsed_ns: 1.0e9,
            from_zero: 8,
            resumed: 12,
            reused: 1,
        };
        let cold = WarmstartArm {
            elapsed_ns: 2.5e9,
            from_zero: 21,
            resumed: 0,
            reused: 0,
        };
        let ws = warmstart_bench_row("tune_halving_warm_vs_cold", 8, warm, cold, true);
        validate_trajectory_row(&ws).expect("warmstart row must validate");
        // but a warm row claiming the reports diverged is rejected
        let bad = warmstart_bench_row("tune_halving_warm_vs_cold", 8, warm, cold, false);
        assert!(validate_trajectory_row(&bad).is_err());

        let sp = speedup_bench_row(
            "des_naive_vs_indexed",
            100_000,
            1,
            ("naive", 9.0e9),
            ("indexed", 3.0e9),
        );
        validate_trajectory_row(&sp).expect("speedup row must validate");
        assert!((sp.get("speedup").as_f64().unwrap() - 3.0).abs() < 1e-12);

        let reach = reachability_bench_row(
            "reachability_100_slices",
            "SYNTH-100x1g",
            100,
            true,
            1,
            40_000.0,
            90.0,
        );
        validate_trajectory_row(&reach).expect("reachability row must validate");

        let arm = |label, usd, viol| PowerBenchArm {
            label,
            makespan_s: 100.0,
            throughput_jps: 0.5,
            energy_per_job_j: 900.0,
            usd_per_job: usd,
            violation_s: viol,
            deferrals: 4,
            price_deferrals: 2,
            parked_gpu_s: 60.0,
        };
        let power = power_bench_row(
            "power_cap_hetero",
            50,
            1200.0,
            arm("uncapped", 0.02, 0.0),
            arm("capped", 0.02, 0.0),
            arm("capped+price-aware", 0.004, 0.0),
        );
        validate_trajectory_row(&power).expect("power row must validate");
        assert!((power.get("usd_per_job_ratio").as_f64().unwrap() - 5.0).abs() < 1e-9);
        // a governed arm reporting violation seconds is rejected
        let bad = power_bench_row(
            "power_cap_hetero",
            50,
            1200.0,
            arm("uncapped", 0.02, 0.0),
            arm("capped", 0.02, 1.5),
            arm("capped+price-aware", 0.004, 0.0),
        );
        let err = validate_trajectory_row(&bad).unwrap_err();
        assert!(err.contains("violation_s"), "{err}");

        // the fault row built by the real builder is validated in
        // scheduler::fault's tests (it needs a full fault run).
    }

    #[test]
    fn validator_rejects_unknown_and_truncated_rows() {
        assert!(validate_trajectory_row(&Json::Null).is_err());
        let unknown = Json::obj(vec![("schema", Json::str("migm.bench.mystery.v9"))]);
        assert!(validate_trajectory_row(&unknown).is_err());
        let truncated = Json::obj(vec![
            ("schema", Json::str("migm.bench.fleet.v1")),
            ("bench", Json::str("x")),
        ]);
        let err = validate_trajectory_row(&truncated).unwrap_err();
        assert!(err.contains("missing key"), "{err}");
    }
}
