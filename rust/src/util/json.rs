//! Minimal JSON parser/serializer (offline build has no serde_json).
//!
//! Used by the artifact manifest loader (`runtime::manifest`), the
//! JSON-lines job server (`server`), and the experiment config loader
//! (`config`). Supports the full JSON grammar minus exotic number forms;
//! numbers are f64 (adequate for every payload we exchange).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number; all numbers are f64 here.
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object; `BTreeMap` keeps serialization key order stable.
    Obj(BTreeMap<String, Json>),
}

/// Parse failure: byte offset plus a short message.
#[derive(Debug, thiserror::Error, PartialEq)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub pos: usize,
    /// What the parser expected or saw.
    pub msg: String,
}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number value truncated to u64, if this is a non-negative `Num`.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as u64)
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["k"]` access; Null for misses (chainable).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array indexing; Null for misses.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|v| v.get(i)).unwrap_or(&NULL)
    }

    /// True for `Null` — the miss value returned by `get`/`at`.
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ----------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

impl fmt::Display for Json {
    /// Compact serialization (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequence.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": true}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(1).get("b").as_str(), Some("x"));
        assert!(v.get("a").at(2).is_null());
        assert_eq!(v.get("c").as_bool(), Some(true));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
        let w = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(w.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "{\"a\":}"] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn parses_real_manifest_shape() {
        let s = r#"{"decode": {"decode_s128": {"file": "decode_s128.hlo.txt",
                    "params": [{"name": "embedding", "shape": [512, 256]}],
                    "kv_shape": [2, 8, 4, 128, 64], "kv_cache_bytes": 2097152}}}"#;
        let v = Json::parse(s).unwrap();
        let e = v.get("decode").get("decode_s128");
        assert_eq!(e.get("file").as_str(), Some("decode_s128.hlo.txt"));
        let shape: Vec<u64> = e.get("params").at(0).get("shape").as_arr().unwrap()
            .iter().map(|x| x.as_u64().unwrap()).collect();
        assert_eq!(shape, vec![512, 256]);
        assert_eq!(e.get("kv_cache_bytes").as_u64(), Some(2097152));
    }
}
