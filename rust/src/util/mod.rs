//! Small in-tree substrates the offline build cannot pull from crates.io:
//! a deterministic PRNG ([`rng`]), a JSON codec ([`json`]), a
//! criterion-style micro-bench harness ([`bench`]), and the bit-exact
//! scalar codecs checkpoint snapshots are built from ([`snap`]).

pub mod bench;
pub mod json;
pub mod rng;
pub mod snap;

pub use json::Json;
pub use rng::Rng;
