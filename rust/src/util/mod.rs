//! Small in-tree substrates the offline build cannot pull from crates.io:
//! a deterministic PRNG ([`rng`]), a JSON codec ([`json`]), and a
//! criterion-style micro-bench harness ([`bench`]).

pub mod bench;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
