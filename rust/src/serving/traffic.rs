//! Request generation for the serving subsystem: time-varying
//! (diurnal / bursty) request streams built on `workloads::mix`'s
//! arrival machinery — non-homogeneous Poisson via thinning — plus
//! replay of explicit arrival traces (e.g. parsed from JSON with
//! [`ArrivalProcess::trace_from_json`]). Arrival times and request
//! shapes are drawn from independent seeded streams, so the same seed
//! always yields the same workload bit-for-bit.

use crate::util::Rng;
use crate::workloads::mix::{ArrivalProcess, RateProfile};

/// One inference request: when it arrives and its token shape. The
/// prompt is absorbed in prefill chunks; every decoded token is one
/// batch iteration and one KV-cache slot-token.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Request id, dense from 0 in arrival order.
    pub id: u64,
    /// Arrival time, s.
    pub arrival_s: f64,
    /// Prompt length, tokens.
    pub prompt_tokens: u32,
    /// Requested completion length, tokens.
    pub decode_tokens: u32,
}

impl Request {
    /// KV-cache footprint at completion, in tokens.
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens as u64 + self.decode_tokens as u64
    }
}

/// How a serving run's request stream is produced.
#[derive(Debug, Clone)]
pub enum TrafficConfig {
    /// `n_requests` arrivals over a diurnal / bursty [`RateProfile`]
    /// (non-homogeneous Poisson, sampled by thinning).
    Diurnal {
        /// Total arrivals to draw.
        n_requests: usize,
        /// The λ(t) shape arrivals are thinned against.
        profile: RateProfile,
    },
    /// Replay explicit arrival times (sorted seconds); request shapes
    /// are still drawn from the seeded shape stream.
    Replay {
        /// Sorted absolute arrival times, s.
        arrivals: Vec<f64>,
    },
}

/// Prompt-length range (tokens), uniform: `32..=224`.
const PROMPT_LO: usize = 32;
const PROMPT_SPAN: usize = 193;
/// Decode-length range (tokens), uniform: `16..=112`.
const DECODE_LO: usize = 16;
const DECODE_SPAN: usize = 97;

impl TrafficConfig {
    /// The canonical synthetic 24h day, time-compressed so that
    /// `n_requests` span exactly one period: night trough at 0.5
    /// req/s, midday peak at 20 req/s (sinusoid, mean 10.25 req/s),
    /// and an evening flash-crowd burst at 1.3x. The *shape* is a
    /// full day; the wall-clock is scaled so runs of any size
    /// exercise a whole trough-peak-trough cycle.
    pub fn compressed_day(n_requests: usize) -> TrafficConfig {
        let profile = RateProfile::diurnal(0.5, 20.0, n_requests as f64 / 10.25);
        let period = profile.period_s;
        TrafficConfig::Diurnal {
            n_requests,
            profile: profile.with_burst(0.62 * period, 0.06 * period, 1.3),
        }
    }

    /// Number of requests this config will generate.
    pub fn n_requests(&self) -> usize {
        match self {
            TrafficConfig::Diurnal { n_requests, .. } => *n_requests,
            TrafficConfig::Replay { arrivals } => arrivals.len(),
        }
    }

    /// Materialize the request stream. Deterministic per seed: the
    /// arrival process and the shape stream use decorrelated
    /// sub-seeds of `seed`.
    pub fn generate(&self, seed: u64) -> Vec<Request> {
        let arrivals = match self {
            TrafficConfig::Diurnal {
                n_requests,
                profile,
            } => ArrivalProcess::NonHomogeneous(profile.clone()).sample(*n_requests, seed),
            TrafficConfig::Replay { arrivals } => {
                assert!(
                    arrivals.windows(2).all(|w| w[0] <= w[1]),
                    "replay arrivals must be sorted"
                );
                arrivals.clone()
            }
        };
        let mut shapes = Rng::new(seed ^ 0x5eed_7a11_ca11_ab1e);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, arrival_s)| Request {
                id: i as u64,
                arrival_s,
                prompt_tokens: (PROMPT_LO + shapes.below(PROMPT_SPAN)) as u32,
                decode_tokens: (DECODE_LO + shapes.below(DECODE_SPAN)) as u32,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_seed_deterministic() {
        let cfg = TrafficConfig::compressed_day(300);
        let a = cfg.generate(9);
        let b = cfg.generate(9);
        let c = cfg.generate(10);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 300);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    }

    #[test]
    fn shapes_stay_in_range() {
        for r in TrafficConfig::compressed_day(200).generate(3) {
            assert!((32..=224).contains(&r.prompt_tokens), "{r:?}");
            assert!((16..=112).contains(&r.decode_tokens), "{r:?}");
            assert_eq!(r.total_tokens(), (r.prompt_tokens + r.decode_tokens) as u64);
        }
    }

    #[test]
    fn replay_preserves_arrival_times() {
        let cfg = TrafficConfig::Replay {
            arrivals: vec![0.0, 1.0, 5.0],
        };
        let reqs = cfg.generate(1);
        assert_eq!(cfg.n_requests(), 3);
        assert_eq!(
            reqs.iter().map(|r| r.arrival_s).collect::<Vec<_>>(),
            vec![0.0, 1.0, 5.0]
        );
        // ids are assigned in arrival order
        assert_eq!(reqs[2].id, 2);
    }

    #[test]
    fn compressed_day_spans_one_period() {
        let cfg = TrafficConfig::compressed_day(500);
        let TrafficConfig::Diurnal { profile, .. } = &cfg else {
            panic!("compressed_day is diurnal");
        };
        // mean of the sinusoid x period == n_requests by construction
        assert!((profile.mean_rps() * profile.period_s - 500.0).abs() < 1e-6);
        assert_eq!(profile.bursts.len(), 1);
    }
}
