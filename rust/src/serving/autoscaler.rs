//! SLO-driven autoscaling policy. Pure decision logic over a
//! [`LoadSnapshot`] — the engine executes the returned action via the
//! orchestrator's transactional `PartitionPlan` paths
//! (`reserve_instances` / `release_instances` / `swap_instance`).
//!
//! The ladder has two rungs in each direction:
//!
//! * scale **up** under SLO pressure — first promote an eco replica
//!   to the fast MIG profile (cheap: one transactional swap), then
//!   add replicas up to `max_replicas`;
//! * scale **down** in troughs — first drain and release surplus
//!   replicas down to `min_replicas`, then demote the last idle
//!   replica to the eco profile, cutting standby draw to save energy
//!   until load returns.

/// Tunable thresholds. All SLO fractions are against the p99 target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalerKnobs {
    /// Seconds between policy evaluations.
    pub interval_s: f64,
    /// Minimum seconds between consecutive scale actions.
    pub cooldown_s: f64,
    /// Floor on live replicas.
    pub min_replicas: usize,
    /// Ceiling on live replicas.
    pub max_replicas: usize,
    /// Scale up when the recent-window p99 exceeds this fraction of
    /// the SLO.
    pub up_p99_frac: f64,
    /// Scale down only when the recent-window p99 sits below this
    /// fraction of the SLO.
    pub down_p99_frac: f64,
    /// Scale up when queue depth exceeds this multiple of the fleet's
    /// total batch slots.
    pub queue_high_factor: f64,
    /// Scale up when the oldest queued request has already waited
    /// this fraction of the SLO (early-warning signal — fires before
    /// any completion shows up slow in the window).
    pub wait_frac: f64,
}

impl Default for AutoscalerKnobs {
    fn default() -> AutoscalerKnobs {
        AutoscalerKnobs {
            interval_s: 10.0,
            cooldown_s: 25.0,
            min_replicas: 1,
            max_replicas: 3,
            up_p99_frac: 0.8,
            down_p99_frac: 0.25,
            queue_high_factor: 2.0,
            wait_frac: 0.35,
        }
    }
}

impl AutoscalerKnobs {
    /// Knobs rescaled for short compressed traces (smoke runs): same
    /// thresholds, faster evaluation cadence.
    pub fn fast(interval_s: f64, cooldown_s: f64) -> AutoscalerKnobs {
        AutoscalerKnobs {
            interval_s,
            cooldown_s,
            ..AutoscalerKnobs::default()
        }
    }
}

/// What the engine shows the policy at each evaluation tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadSnapshot {
    /// Evaluation-tick time, s.
    pub t_s: f64,
    /// Requests waiting for a slot.
    pub queue_depth: usize,
    /// Seconds the oldest queued request has waited (0 if none).
    pub oldest_wait_s: f64,
    /// Requests currently in some replica's batch.
    pub in_flight: usize,
    /// Live, non-draining replicas.
    pub replicas: usize,
    /// Total batch slots across those replicas.
    pub total_slots: usize,
    /// Recent-window p99 turnaround (None before any completion).
    pub window_p99_s: Option<f64>,
    /// Any live replica currently on the eco profile.
    pub has_eco: bool,
    /// Exactly one live replica, fast profile, fully idle.
    pub sole_fast_idle: bool,
}

/// The policy's verdict; the engine maps it onto `PartitionPlan`s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// No change this tick.
    Hold,
    /// Provision one more replica.
    AddReplica,
    /// Drain and release one replica.
    RemoveReplica,
    /// Swap an eco replica to the fast MIG profile.
    PromoteProfile,
    /// Swap the last idle fast replica down to the eco profile.
    DemoteProfile,
}

impl ScaleAction {
    /// True for capacity-adding actions.
    pub fn is_up(self) -> bool {
        matches!(self, ScaleAction::AddReplica | ScaleAction::PromoteProfile)
    }

    /// True for capacity-shedding actions.
    pub fn is_down(self) -> bool {
        matches!(self, ScaleAction::RemoveReplica | ScaleAction::DemoteProfile)
    }

    /// Stable name for reports and events.
    pub fn label(self) -> &'static str {
        match self {
            ScaleAction::Hold => "hold",
            ScaleAction::AddReplica => "add-replica",
            ScaleAction::RemoveReplica => "remove-replica",
            ScaleAction::PromoteProfile => "promote-profile",
            ScaleAction::DemoteProfile => "demote-profile",
        }
    }
}

/// The threshold policy plus its cooldown latch.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    /// The thresholds this policy evaluates.
    pub knobs: AutoscalerKnobs,
    last_action_s: f64,
}

impl Autoscaler {
    /// Policy with the given knobs, no action taken yet.
    pub fn new(knobs: AutoscalerKnobs) -> Autoscaler {
        assert!(knobs.min_replicas >= 1 && knobs.max_replicas >= knobs.min_replicas);
        Autoscaler {
            knobs,
            last_action_s: f64::NEG_INFINITY,
        }
    }

    /// Evaluate one tick. Non-`Hold` verdicts arm the cooldown.
    pub fn decide(&mut self, slo_p99_s: f64, s: &LoadSnapshot) -> ScaleAction {
        let k = self.knobs;
        if s.t_s - self.last_action_s < k.cooldown_s {
            return ScaleAction::Hold;
        }
        let overloaded = s
            .window_p99_s
            .is_some_and(|p| p > k.up_p99_frac * slo_p99_s)
            || s.oldest_wait_s > k.wait_frac * slo_p99_s
            || s.queue_depth as f64 > k.queue_high_factor * s.total_slots.max(1) as f64;
        if overloaded {
            let action = if s.has_eco {
                ScaleAction::PromoteProfile
            } else if s.replicas < k.max_replicas {
                ScaleAction::AddReplica
            } else {
                ScaleAction::Hold
            };
            if action != ScaleAction::Hold {
                self.last_action_s = s.t_s;
            }
            return action;
        }
        let quiet = s.queue_depth == 0
            && s.window_p99_s
                .is_some_and(|p| p < k.down_p99_frac * slo_p99_s)
            && 2 * s.in_flight < s.total_slots.max(1);
        if quiet {
            if s.replicas > k.min_replicas {
                self.last_action_s = s.t_s;
                return ScaleAction::RemoveReplica;
            }
            if s.sole_fast_idle {
                self.last_action_s = s.t_s;
                return ScaleAction::DemoteProfile;
            }
        }
        ScaleAction::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(t: f64) -> LoadSnapshot {
        LoadSnapshot {
            t_s: t,
            queue_depth: 0,
            oldest_wait_s: 0.0,
            in_flight: 0,
            replicas: 1,
            total_slots: 12,
            window_p99_s: None,
            has_eco: false,
            sole_fast_idle: false,
        }
    }

    const SLO: f64 = 15.0;

    #[test]
    fn overload_promotes_eco_before_adding() {
        let mut a = Autoscaler::new(AutoscalerKnobs::default());
        let mut s = snap(100.0);
        s.queue_depth = 100; // >> 2x slots
        s.has_eco = true;
        assert_eq!(a.decide(SLO, &s), ScaleAction::PromoteProfile);
        // cooldown holds the next tick
        s.t_s += 10.0;
        assert_eq!(a.decide(SLO, &s), ScaleAction::Hold);
        // past cooldown, no eco left -> add a replica
        s.t_s += 30.0;
        s.has_eco = false;
        assert_eq!(a.decide(SLO, &s), ScaleAction::AddReplica);
        // at max replicas there is nothing left to do
        s.t_s += 40.0;
        s.replicas = 3;
        assert_eq!(a.decide(SLO, &s), ScaleAction::Hold);
    }

    #[test]
    fn window_tail_and_oldest_wait_both_trigger_up() {
        let mut a = Autoscaler::new(AutoscalerKnobs::default());
        let mut s = snap(50.0);
        s.replicas = 2;
        s.window_p99_s = Some(0.9 * SLO);
        assert!(a.decide(SLO, &s).is_up());
        let mut a2 = Autoscaler::new(AutoscalerKnobs::default());
        let mut s2 = snap(50.0);
        s2.replicas = 2;
        s2.queue_depth = 1;
        s2.oldest_wait_s = 0.5 * SLO;
        assert!(a2.decide(SLO, &s2).is_up());
    }

    #[test]
    fn quiet_trough_removes_then_demotes() {
        let mut a = Autoscaler::new(AutoscalerKnobs::default());
        let mut s = snap(200.0);
        s.replicas = 2;
        s.window_p99_s = Some(0.1 * SLO);
        assert_eq!(a.decide(SLO, &s), ScaleAction::RemoveReplica);
        s.t_s += 30.0;
        s.replicas = 1;
        s.sole_fast_idle = true;
        assert_eq!(a.decide(SLO, &s), ScaleAction::DemoteProfile);
        // an eco sole replica has nowhere down to go
        s.t_s += 30.0;
        s.sole_fast_idle = false;
        assert_eq!(a.decide(SLO, &s), ScaleAction::Hold);
    }

    #[test]
    fn busy_fleet_never_scales_down() {
        let mut a = Autoscaler::new(AutoscalerKnobs::default());
        let mut s = snap(200.0);
        s.replicas = 3;
        s.window_p99_s = Some(0.1 * SLO);
        s.in_flight = 10; // more than half the slots busy
        assert_eq!(a.decide(SLO, &s), ScaleAction::Hold);
        // queued work also blocks scale-down
        s.in_flight = 0;
        s.queue_depth = 1;
        assert_eq!(a.decide(SLO, &s), ScaleAction::Hold);
    }

    #[test]
    fn no_completions_yet_means_no_scale_down() {
        // window_p99 is None at t=0: the policy must not tear down
        // replicas before the first completion lands.
        let mut a = Autoscaler::new(AutoscalerKnobs::default());
        let mut s = snap(100.0);
        s.replicas = 3;
        assert_eq!(a.decide(SLO, &s), ScaleAction::Hold);
    }
}
