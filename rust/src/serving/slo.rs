//! Per-request SLO tracking: every completion is scored against
//! configured p50/p99 latency targets, kept both as a full-run sample
//! (for the final report's attained percentiles) and as a rolling
//! window of recent turnarounds (what the autoscaler reacts to — it
//! must see *current* tail latency, not the whole day's average).

use crate::metrics::{LatencyStats, RollingWindow};

/// Latency targets, milliseconds end-to-end (arrival → last token).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    /// Median turnaround target, ms.
    pub p50_ms: f64,
    /// Tail (p99) turnaround target, ms.
    pub p99_ms: f64,
}

impl SloTargets {
    /// Targets in milliseconds; p99 must be ≥ p50.
    pub fn new(p50_ms: f64, p99_ms: f64) -> SloTargets {
        assert!(p50_ms > 0.0 && p99_ms >= p50_ms);
        SloTargets { p50_ms, p99_ms }
    }

    /// The default serving target: 4s median, 15s tail. Generous in
    /// absolute terms — requests decode up to ~110 tokens at 30-60ms
    /// per iteration — so violations measure queueing/overload, not
    /// raw service time.
    pub fn default_chat() -> SloTargets {
        SloTargets::new(4_000.0, 15_000.0)
    }

    /// The p99 target in seconds.
    pub fn p99_s(&self) -> f64 {
        self.p99_ms / 1_000.0
    }
}

/// Completion-side tracker. `within_slo` counts requests whose
/// turnaround met the p99 target — the numerator of "sustained RPS at
/// the SLO", the subsystem's headline metric.
#[derive(Debug, Clone)]
pub struct SloTracker {
    /// The targets completions are scored against.
    pub targets: SloTargets,
    window: RollingWindow,
    queue_s: Vec<f64>,
    turnaround_s: Vec<f64>,
    within_slo: usize,
}

/// Window size for the autoscaler's recent-tail estimate.
const WINDOW_CAP: usize = 128;

impl SloTracker {
    /// Empty tracker for the given targets.
    pub fn new(targets: SloTargets) -> SloTracker {
        SloTracker {
            targets,
            window: RollingWindow::new(WINDOW_CAP),
            queue_s: Vec::new(),
            turnaround_s: Vec::new(),
            within_slo: 0,
        }
    }

    /// Record one completion (both in seconds).
    pub fn record(&mut self, queue_s: f64, turnaround_s: f64) {
        self.window.push(turnaround_s);
        self.queue_s.push(queue_s);
        self.turnaround_s.push(turnaround_s);
        if turnaround_s * 1_000.0 <= self.targets.p99_ms {
            self.within_slo += 1;
        }
    }

    /// Recent-window p99 turnaround (s); `None` before any completion.
    pub fn window_p99_s(&self) -> Option<f64> {
        self.window.p99()
    }

    /// Completions recorded over the full run.
    pub fn completed(&self) -> usize {
        self.turnaround_s.len()
    }

    /// Completions that met the p99 target.
    pub fn within_slo(&self) -> usize {
        self.within_slo
    }

    /// Full-run attained latency distribution.
    pub fn attained(&self) -> LatencyStats {
        LatencyStats::from_samples(&self.queue_s, &self.turnaround_s)
    }

    /// Headroom against the p99 target, ms: positive means the SLO
    /// was met with room to spare, negative means it was blown.
    pub fn margin_ms(&self) -> f64 {
        self.targets.p99_ms - self.attained().p99_turnaround_s * 1_000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_within_slo_against_p99_target() {
        let mut t = SloTracker::new(SloTargets::new(1_000.0, 2_000.0));
        t.record(0.0, 1.0); // 1000ms <= 2000ms
        t.record(0.5, 2.0); // exactly on target counts
        t.record(3.0, 4.0); // blown
        assert_eq!(t.completed(), 3);
        assert_eq!(t.within_slo(), 2);
    }

    #[test]
    fn margin_is_signed_headroom() {
        let mut t = SloTracker::new(SloTargets::new(1_000.0, 2_000.0));
        t.record(0.0, 0.5);
        assert!((t.margin_ms() - 1_500.0).abs() < 1e-9);
        t.record(0.0, 3.0);
        assert!((t.margin_ms() + 1_000.0).abs() < 1e-9); // p99 = 3s -> -1000ms
    }

    #[test]
    fn window_tracks_recent_not_total() {
        let mut t = SloTracker::new(SloTargets::default_chat());
        assert_eq!(t.window_p99_s(), None);
        // Fill the window with slow samples, then push enough fast
        // ones to evict them all: the window p99 must recover even
        // though the full-run p99 stays slow.
        for _ in 0..WINDOW_CAP {
            t.record(0.0, 60.0);
        }
        for _ in 0..WINDOW_CAP {
            t.record(0.0, 0.1);
        }
        assert_eq!(t.window_p99_s(), Some(0.1));
        assert!(t.attained().p99_turnaround_s > 1.0);
    }
}
