//! Online LLM serving over MIG fleets: continuous batching, SLO
//! tracking, and SLO-driven autoscaling, layered on the existing
//! [`Orchestrator`] seams the PJRT [`server`](crate::server) already
//! uses — `reserve_instances` / `release_instances` /
//! [`swap_instance`](Orchestrator::swap_instance) for transactional
//! replica placement, the external-job ledger for per-request latency
//! accounting, and the [`BeliefLedger`](crate::estimator::BeliefLedger)
//! (`observe_external` + `apply_external_fit`) for confidence-band KV
//! admission.
//!
//! The engine is a deterministic discrete-event loop: arrivals come
//! from [`traffic`] (diurnal non-homogeneous Poisson or trace
//! replay), each replica's [`batcher`] advances one batch iteration
//! at a time, [`slo`] scores completions against p50/p99 targets, and
//! the [`autoscaler`] watches SLO headroom and queue depth to scale
//! replica count and MIG profile both ways — including trough
//! scale-down to save energy. Everything is seeded: the same
//! [`ServeConfig`] yields a byte-identical [`ServeReport`] on every
//! run, regardless of thread count (the engine is single-threaded by
//! construction).
//!
//! The headline metric is **sustained RPS at the p99 SLO** — requests
//! completed within target per second of trace — alongside
//! **J/request**, where the elastic fleet earns its keep in troughs.

pub mod autoscaler;
pub mod batcher;
pub mod slo;
pub mod traffic;

use std::collections::VecDeque;
use std::sync::Arc;

use crate::estimator::Estimate;
use crate::metrics::{BatchMetrics, LatencyStats};
use crate::mig::InstanceId;
use crate::predictor::host::fit_one;
use crate::predictor::Z_99;
use crate::scheduler::scheme_b::SchemeBPolicy;
use crate::scheduler::Orchestrator;
use crate::util::Json;
use crate::GpuSpec;

pub use autoscaler::{Autoscaler, AutoscalerKnobs, LoadSnapshot, ScaleAction};
pub use batcher::Batcher;
pub use slo::{SloTargets, SloTracker};
pub use traffic::{Request, TrafficConfig};

/// The serving engine drives a Scheme-B orchestrator purely through
/// its server hooks (same seam as the PJRT server).
type ServeOrchestrator = Orchestrator<SchemeBPolicy>;

/// Static shape of the model being served. Iteration latency follows
/// the repo's wave model: an instance with fewer GPCs than
/// `demand_gpcs` runs `ceil(demand / gpcs)` compute waves per step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelProfile {
    /// Model name.
    pub name: &'static str,
    /// Weights resident per replica, GB.
    pub weights_gb: f64,
    /// KV cache per token, MB.
    pub kv_mb_per_token: f64,
    /// Decode-iteration latency at full (`demand_gpcs`) compute, s.
    pub step_s_full: f64,
    /// Compute demand in GPC units.
    pub demand_gpcs: u8,
    /// Prompt tokens absorbed per prefill iteration.
    pub prefill_chunk: u32,
}

impl ModelProfile {
    /// The 7B chat model the LLM batch experiments already use.
    pub fn qwen2_7b() -> ModelProfile {
        ModelProfile {
            name: "qwen2-7b",
            weights_gb: 7.0,
            kv_mb_per_token: 0.8,
            step_s_full: 0.03,
            demand_gpcs: 2,
            prefill_chunk: 64,
        }
    }

    /// KV cache per token, GB.
    pub fn kv_gb_per_token(&self) -> f64 {
        self.kv_mb_per_token / 1024.0
    }

    /// Iteration latency on an instance with `slices` GPCs.
    pub fn step_s(&self, slices: u8) -> f64 {
        self.step_s_full * self.demand_gpcs.div_ceil(slices.max(1)) as f64
    }
}

/// Full description of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Run label (report key).
    pub label: &'static str,
    /// GPU model replicas are carved from.
    pub gpu: GpuSpec,
    /// The model being served.
    pub model: ModelProfile,
    /// Latency targets the run is scored against.
    pub slo: SloTargets,
    /// Arrival process and request-shape generator.
    pub traffic: TrafficConfig,
    /// Seed for traffic draws.
    pub seed: u64,
    /// Replicas provisioned at t=0.
    pub initial_replicas: usize,
    /// Start replicas on the fast profile (vs eco)?
    pub initial_fast: bool,
    /// Concurrent request slots per replica batcher.
    pub slots_per_replica: usize,
    /// Memory request that resolves to the eco MIG profile
    /// (`1g.10gb` on the A100-80GB).
    pub eco_mem_req_gb: f64,
    /// Memory request that resolves to the fast profile (`2g.20gb`).
    pub fast_mem_req_gb: f64,
    /// `None` = static provisioning (no scaling).
    pub autoscaler: Option<AutoscalerKnobs>,
}

impl ServeConfig {
    /// Autoscaled run over the compressed synthetic 24h day
    /// ([`TrafficConfig::compressed_day`]), starting from one eco
    /// replica. Autoscaler cadence scales with the day length so
    /// short smoke traces still see many evaluation ticks.
    pub fn diurnal(n_requests: usize, seed: u64) -> ServeConfig {
        let traffic = TrafficConfig::compressed_day(n_requests);
        let period_s = match &traffic {
            TrafficConfig::Diurnal { profile, .. } => profile.period_s,
            TrafficConfig::Replay { .. } => unreachable!("compressed_day is diurnal"),
        };
        let knobs = AutoscalerKnobs::fast((period_s / 40.0).max(2.0), (period_s / 16.0).max(5.0));
        ServeConfig {
            label: "serve-auto",
            gpu: GpuSpec::a100_80gb(),
            model: ModelProfile::qwen2_7b(),
            slo: SloTargets::default_chat(),
            traffic,
            seed,
            initial_replicas: 1,
            initial_fast: false,
            slots_per_replica: 12,
            eco_mem_req_gb: 8.5,
            fast_mem_req_gb: 12.0,
            autoscaler: Some(knobs),
        }
    }

    /// The `migm serve --smoke` configuration: one compressed day of
    /// 240 requests.
    pub fn smoke(seed: u64) -> ServeConfig {
        let mut cfg = ServeConfig::diurnal(240, seed);
        cfg.label = "serve-smoke";
        cfg
    }

    /// Turn this run into the static-provisioning arm: `replicas`
    /// fast replicas, no autoscaler. The head-to-head baseline.
    pub fn static_fast(mut self, replicas: usize) -> ServeConfig {
        self.label = "serve-static";
        self.autoscaler = None;
        self.initial_replicas = replicas;
        self.initial_fast = true;
        self
    }
}

/// One scale action the engine executed (recorded at initiation).
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleEvent {
    /// Simulated time the action was initiated, s.
    pub t_s: f64,
    /// What the autoscaler did.
    pub action: ScaleAction,
    /// Live replicas right after the action was initiated.
    pub replicas_after: usize,
}

/// Final report of one serving run. [`ServeReport::to_json`] is
/// byte-stable per seed — the determinism test compares full JSON
/// strings.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Run label.
    pub label: String,
    /// GPU-model name.
    pub gpu: String,
    /// Traffic seed.
    pub seed: u64,
    /// The latency targets scored against.
    pub slo: SloTargets,
    /// Requests offered.
    pub n_requests: usize,
    /// Requests completed.
    pub completed: usize,
    /// Requests that met the p99 SLO.
    pub within_slo: usize,
    /// Time of the last completion (s).
    pub duration_s: f64,
    /// Requests-within-SLO per second — the headline metric.
    pub sustained_rps: f64,
    /// Per-request latency percentiles.
    pub latency: LatencyStats,
    /// p99 headroom vs the SLO target, ms (negative = blown).
    pub slo_margin_ms: f64,
    /// Total energy over the run, J.
    pub energy_j: f64,
    /// Energy per completed request, J.
    pub j_per_request: f64,
    /// Time-averaged utilized GPCs (slice-seconds / duration).
    pub mean_busy_gpcs: f64,
    /// Time-averaged (weights + KV) footprint over total GPU memory.
    pub mem_utilization: f64,
    /// Fits whose projected demand exceeded the replica's memory —
    /// admission was paused by the confidence band.
    pub kv_alerts: u64,
    /// Replica additions executed.
    pub scale_ups: usize,
    /// Replica removals executed.
    pub scale_downs: usize,
    /// Eco→fast profile swaps executed.
    pub promotions: usize,
    /// Fast→eco profile swaps executed.
    pub demotions: usize,
    /// Fewest live replicas seen.
    pub replicas_min: usize,
    /// Most live replicas seen.
    pub replicas_max: usize,
    /// Simulated seconds spent provisioning/swapping replicas.
    pub reconfig_time_s: f64,
    /// Every scale action, in initiation order.
    pub events: Vec<ScaleEvent>,
}

impl ServeReport {
    /// Byte-stable JSON document (`migm.serve.report.v1`).
    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("t_s", Json::num(e.t_s)),
                    ("action", Json::str(e.action.label())),
                    ("replicas_after", Json::num(e.replicas_after as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("migm.serve.report.v1")),
            ("label", Json::str(self.label.clone())),
            ("gpu", Json::str(self.gpu.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("slo_p50_ms", Json::num(self.slo.p50_ms)),
            ("slo_p99_ms", Json::num(self.slo.p99_ms)),
            ("n_requests", Json::num(self.n_requests as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("within_slo", Json::num(self.within_slo as f64)),
            ("duration_s", Json::num(self.duration_s)),
            ("sustained_rps", Json::num(self.sustained_rps)),
            ("p50_turnaround_s", Json::num(self.latency.p50_turnaround_s)),
            ("p99_turnaround_s", Json::num(self.latency.p99_turnaround_s)),
            ("p99_queue_s", Json::num(self.latency.p99_queue_s)),
            ("slo_margin_ms", Json::num(self.slo_margin_ms)),
            ("energy_j", Json::num(self.energy_j)),
            ("j_per_request", Json::num(self.j_per_request)),
            ("mean_busy_gpcs", Json::num(self.mean_busy_gpcs)),
            ("mem_utilization", Json::num(self.mem_utilization)),
            ("kv_alerts", Json::num(self.kv_alerts as f64)),
            ("scale_ups", Json::num(self.scale_ups as f64)),
            ("scale_downs", Json::num(self.scale_downs as f64)),
            ("promotions", Json::num(self.promotions as f64)),
            ("demotions", Json::num(self.demotions as f64)),
            ("replicas_min", Json::num(self.replicas_min as f64)),
            ("replicas_max", Json::num(self.replicas_max as f64)),
            ("reconfig_time_s", Json::num(self.reconfig_time_s)),
            ("events", Json::Arr(events)),
        ])
    }

    /// Human-readable summary table.
    pub fn render(&self) -> String {
        let mut t = crate::metrics::Table::new(&["metric", "value"]);
        let mut kv = |k: &str, v: String| t.row(vec![k.to_string(), v]);
        kv("run", self.label.clone());
        kv("gpu / seed", format!("{} / {}", self.gpu, self.seed));
        kv(
            "requests (completed/total)",
            format!("{}/{}", self.completed, self.n_requests),
        );
        kv("duration (s)", format!("{:.1}", self.duration_s));
        kv(
            "sustained RPS @ p99 SLO",
            format!(
                "{:.2} ({} within {:.0}ms)",
                self.sustained_rps, self.within_slo, self.slo.p99_ms
            ),
        );
        kv(
            "turnaround p50/p99 (s)",
            format!(
                "{:.2}/{:.2}",
                self.latency.p50_turnaround_s, self.latency.p99_turnaround_s
            ),
        );
        kv("p99-vs-SLO margin (ms)", format!("{:+.0}", self.slo_margin_ms));
        kv(
            "energy (J) / per request",
            format!("{:.0} / {:.1}", self.energy_j, self.j_per_request),
        );
        kv(
            "scale events (up/down)",
            format!(
                "{}/{} (promote {}, demote {})",
                self.scale_ups, self.scale_downs, self.promotions, self.demotions
            ),
        );
        kv(
            "replicas (min..max)",
            format!("{}..{}", self.replicas_min, self.replicas_max),
        );
        kv("kv-band admission alerts", format!("{}", self.kv_alerts));
        t.render()
    }

    /// Project onto the batch-metrics shape the online report renders.
    pub fn as_batch_metrics(&self) -> BatchMetrics {
        BatchMetrics {
            n_jobs: self.completed,
            makespan_s: self.duration_s,
            throughput_jps: self.completed as f64 / self.duration_s.max(1e-9),
            energy_j: self.energy_j,
            energy_per_job_j: self.j_per_request,
            mem_utilization: self.mem_utilization,
            avg_turnaround_s: self.latency.mean_turnaround_s,
            reconfig_ops: self.scale_ups + self.scale_downs,
            reconfig_windows: self.events.len(),
            reconfig_time_s: self.reconfig_time_s,
            oom_restarts: 0,
            early_restarts: 0,
        }
    }
}

/// Fraction of a provisioned-but-idle replica's compute draw (weights
/// resident, memory refresh): the energy cost of standing capacity,
/// which trough scale-down eliminates.
const STANDBY_FRAC: f64 = 0.35;
/// Refit the KV belief every this many batch iterations.
const FIT_EVERY: u64 = 16;
/// Fit over the most recent observations only.
const FIT_WINDOW: usize = 96;

struct Replica {
    instance: InstanceId,
    slices: u8,
    mem_gb: f64,
    batcher: Batcher,
    /// Provisioning (weight load / swap) completes at this time.
    ready_at: f64,
    next_tick: Option<f64>,
    draining: bool,
    /// Pending profile swap: `Some(true)` promote, `Some(false)` demote.
    swap_target: Option<bool>,
    iters: u64,
}

impl Replica {
    fn accepts_work(&self, t: f64) -> bool {
        !self.draining && self.swap_target.is_none() && self.ready_at <= t
    }
}

struct Engine<'a> {
    cfg: &'a ServeConfig,
    orch: ServeOrchestrator,
    requests: Vec<Request>,
    next_req: usize,
    /// (request index, external-ledger token), FIFO.
    queue: VecDeque<(usize, u64)>,
    replicas: Vec<Replica>,
    slo: SloTracker,
    scaler: Option<Autoscaler>,
    next_scale_t: f64,
    t: f64,
    last_energy_t: f64,
    energy_j: f64,
    gpc_integral: f64,
    mem_integral: f64,
    kv_alerts: u64,
    events: Vec<ScaleEvent>,
    replicas_min: usize,
    replicas_max: usize,
    reconfig_time_s: f64,
}

/// Run one serving simulation to completion.
pub fn run(cfg: &ServeConfig) -> ServeReport {
    let spec = Arc::new(cfg.gpu.clone());
    let orch = Orchestrator::single(spec.clone(), false, SchemeBPolicy::new(spec));
    let requests = cfg.traffic.generate(cfg.seed);
    let next_scale_t = cfg
        .autoscaler
        .as_ref()
        .map_or(f64::INFINITY, |k| k.interval_s);
    let mut eng = Engine {
        cfg,
        orch,
        requests,
        next_req: 0,
        queue: VecDeque::new(),
        replicas: Vec::new(),
        slo: SloTracker::new(cfg.slo),
        scaler: cfg.autoscaler.map(Autoscaler::new),
        next_scale_t,
        t: 0.0,
        last_energy_t: 0.0,
        energy_j: 0.0,
        gpc_integral: 0.0,
        mem_integral: 0.0,
        kv_alerts: 0,
        events: Vec::new(),
        replicas_min: cfg.initial_replicas,
        replicas_max: cfg.initial_replicas,
        reconfig_time_s: 0.0,
    };
    for _ in 0..cfg.initial_replicas {
        let r = eng
            .spawn_replica(cfg.initial_fast, 0.0)
            .expect("initial replicas must place");
        eng.replicas.push(r);
    }
    eng.run_loop();
    eng.report()
}

impl Engine<'_> {
    /// Reserve a MIG instance + register a fresh KV belief; `ready_at`
    /// models profile creation plus weight load over PCIe.
    fn spawn_replica(&mut self, fast: bool, now: f64) -> Result<Replica, crate::mig::MigError> {
        let model = &self.cfg.model;
        let mem_req = if fast {
            self.cfg.fast_mem_req_gb
        } else {
            self.cfg.eco_mem_req_gb
        };
        let ids = self
            .orch
            .reserve_instances(0, mem_req, model.demand_gpcs, 1)?;
        let instance = ids[0];
        let mgr = &self.orch.gpu(0).mgr;
        let mem_gb = mgr.mem_gb_of(instance).expect("fresh instance");
        let slices = mgr.compute_slices_of(instance).expect("fresh instance");
        let belief = self
            .orch
            .beliefs_mut()
            .register(Estimate::unknown_upfront(model.demand_gpcs), 0.0);
        let provision_s = if now > 0.0 {
            self.cfg.gpu.reconfig_create_s + model.weights_gb / self.cfg.gpu.pcie_gbps
        } else {
            0.0 // initial fleet is pre-warmed
        };
        self.reconfig_time_s += provision_s;
        Ok(Replica {
            instance,
            slices,
            mem_gb,
            batcher: Batcher::new(
                belief,
                self.cfg.slots_per_replica,
                mem_gb,
                model.weights_gb,
                model.kv_gb_per_token(),
            ),
            ready_at: now + provision_s,
            next_tick: None,
            draining: false,
            swap_target: None,
            iters: 0,
        })
    }

    /// Execute deferred transitions that need a drained batch:
    /// release draining replicas, perform pending profile swaps.
    fn settle_transitions(&mut self) {
        let t = self.t;
        let mut i = 0;
        while i < self.replicas.len() {
            if self.replicas[i].draining && self.replicas[i].batcher.is_idle() {
                let inst = self.replicas[i].instance;
                self.orch
                    .release_instances(0, &[inst])
                    .expect("draining replica owns its instance");
                self.replicas.remove(i);
                continue;
            }
            if self.replicas[i].swap_target.is_some() && self.replicas[i].batcher.is_idle() {
                let fast = self.replicas[i].swap_target.take().expect("checked");
                let mem_req = if fast {
                    self.cfg.fast_mem_req_gb
                } else {
                    self.cfg.eco_mem_req_gb
                };
                let old = self.replicas[i].instance;
                match self
                    .orch
                    .swap_instance(0, old, mem_req, self.cfg.model.demand_gpcs)
                {
                    Ok(new_inst) => {
                        let mgr = &self.orch.gpu(0).mgr;
                        let mem_gb = mgr.mem_gb_of(new_inst).expect("swapped instance");
                        let slices = mgr.compute_slices_of(new_inst).expect("swapped instance");
                        let r = &mut self.replicas[i];
                        r.instance = new_inst;
                        r.mem_gb = mem_gb;
                        r.slices = slices;
                        r.batcher.rebudget(mem_gb);
                        let swap_s = self.cfg.gpu.reconfig_destroy_s
                            + self.cfg.gpu.reconfig_create_s
                            + self.cfg.model.weights_gb / self.cfg.gpu.pcie_gbps;
                        r.ready_at = t + swap_s;
                        self.reconfig_time_s += swap_s;
                    }
                    Err(_) => {
                        // Swap target unplaceable (fragmentation):
                        // keep serving on the current profile.
                    }
                }
            }
            i += 1;
        }
        self.replicas_min = self.replicas_min.min(self.replicas.len());
        self.replicas_max = self.replicas_max.max(self.replicas.len());
    }

    fn advance_energy(&mut self, to: f64) {
        let dt = to - self.last_energy_t;
        self.last_energy_t = to;
        if dt <= 0.0 {
            return;
        }
        let spec = &self.cfg.gpu;
        let mut gpcs = 0.0;
        let mut busy_gpcs = 0.0;
        let mut mem = 0.0;
        for r in &self.replicas {
            let busy = r.batcher.busy_slots() as f64 / r.batcher.n_slots() as f64;
            gpcs += r.slices as f64 * busy.max(STANDBY_FRAC);
            busy_gpcs += r.slices as f64 * busy;
            mem += r.batcher.used_gb();
        }
        // Draw comes from the spec's power model; the Legacy arm of
        // `whole_gpu_w` is the exact linear expression this loop used
        // inline, so default-model serve reports are byte-identical.
        self.energy_j += spec.power.whole_gpu_w(spec, gpcs) * dt;
        self.gpc_integral += busy_gpcs * dt;
        self.mem_integral += mem * dt;
    }

    fn run_iteration(&mut self, i: usize) {
        let t = self.t;
        self.replicas[i].iters += 1;
        let finished = self.replicas[i].batcher.step(self.cfg.model.prefill_chunk);
        for s in &finished {
            self.orch.complete_external(s.token, t);
            self.slo.record(s.start_s - s.arrival_s, t - s.arrival_s);
        }
        self.replicas[i].batcher.observe(self.orch.beliefs_mut());
        if self.replicas[i].iters % FIT_EVERY == 0 {
            let belief = self.replicas[i].batcher.belief;
            let (m, r) = {
                let (m, r) = self
                    .orch
                    .beliefs()
                    .get(belief)
                    .external_series()
                    .expect("observed every iteration");
                let lo = m.len().saturating_sub(FIT_WINDOW);
                (m[lo..].to_vec(), r[lo..].to_vec())
            };
            let stats = fit_one(&m, &r, m.len() as f64 * 1.5, Z_99);
            let demand = self.orch.beliefs_mut().apply_external_fit(belief, &stats);
            if demand > self.replicas[i].mem_gb {
                self.kv_alerts += 1;
            }
        }
        self.replicas[i].next_tick = if self.replicas[i].batcher.is_idle() {
            None
        } else {
            Some(t + self.cfg.model.step_s(self.replicas[i].slices))
        };
    }

    /// Admit queued requests into replicas, least-loaded first.
    fn feed(&mut self) {
        let t = self.t;
        while let Some(&(ri, token)) = self.queue.front() {
            let mut order: Vec<usize> = (0..self.replicas.len())
                .filter(|&i| {
                    let r = &self.replicas[i];
                    r.accepts_work(t) && r.batcher.busy_slots() < r.batcher.n_slots()
                })
                .collect();
            order.sort_by_key(|&i| (self.replicas[i].batcher.busy_slots(), i));
            let mut placed = false;
            for &i in &order {
                let admitted = self.replicas[i].batcher.admit(
                    self.orch.beliefs(),
                    &self.requests[ri],
                    token,
                    t,
                );
                if admitted {
                    self.orch.start_external(token, t);
                    let step = self.cfg.model.step_s(self.replicas[i].slices);
                    let r = &mut self.replicas[i];
                    if r.next_tick.is_none() {
                        r.next_tick = Some(t + step);
                    }
                    placed = true;
                    break;
                }
            }
            if !placed {
                break;
            }
            self.queue.pop_front();
        }
    }

    fn snapshot(&self) -> LoadSnapshot {
        let live: Vec<&Replica> = self.replicas.iter().filter(|r| !r.draining).collect();
        let in_flight: usize = self.replicas.iter().map(|r| r.batcher.busy_slots()).sum();
        let has_eco = live
            .iter()
            .any(|r| r.swap_target.is_none() && r.slices < self.cfg.model.demand_gpcs);
        let sole_fast_idle = live.len() == 1
            && live[0].swap_target.is_none()
            && live[0].slices >= self.cfg.model.demand_gpcs
            && live[0].batcher.is_idle();
        let oldest_wait_s = self
            .queue
            .front()
            .map_or(0.0, |&(ri, _)| self.t - self.requests[ri].arrival_s);
        LoadSnapshot {
            t_s: self.t,
            queue_depth: self.queue.len(),
            oldest_wait_s,
            in_flight,
            replicas: live.len(),
            total_slots: live.iter().map(|r| r.batcher.n_slots()).sum(),
            window_p99_s: self.slo.window_p99_s(),
            has_eco,
            sole_fast_idle,
        }
    }

    fn apply_action(&mut self, action: ScaleAction) {
        let t = self.t;
        match action {
            ScaleAction::Hold => return,
            ScaleAction::AddReplica => match self.spawn_replica(true, t) {
                Ok(r) => self.replicas.push(r),
                Err(_) => return, // no slice available: nothing changed
            },
            ScaleAction::RemoveReplica => {
                // Drain the least-loaded removable replica.
                let victim = (0..self.replicas.len())
                    .filter(|&i| {
                        !self.replicas[i].draining && self.replicas[i].swap_target.is_none()
                    })
                    .min_by_key(|&i| (self.replicas[i].batcher.busy_slots(), usize::MAX - i));
                match victim {
                    Some(i) => self.replicas[i].draining = true,
                    None => return,
                }
            }
            ScaleAction::PromoteProfile => {
                let target = (0..self.replicas.len())
                    .filter(|&i| {
                        let r = &self.replicas[i];
                        !r.draining
                            && r.swap_target.is_none()
                            && r.slices < self.cfg.model.demand_gpcs
                    })
                    .min_by_key(|&i| (self.replicas[i].batcher.busy_slots(), i));
                match target {
                    Some(i) => self.replicas[i].swap_target = Some(true),
                    None => return,
                }
            }
            ScaleAction::DemoteProfile => {
                let target = (0..self.replicas.len()).find(|&i| {
                    let r = &self.replicas[i];
                    !r.draining
                        && r.swap_target.is_none()
                        && r.slices >= self.cfg.model.demand_gpcs
                        && r.batcher.is_idle()
                });
                match target {
                    Some(i) => self.replicas[i].swap_target = Some(false),
                    None => return,
                }
            }
        }
        let live = self.replicas.iter().filter(|r| !r.draining).count();
        self.events.push(ScaleEvent {
            t_s: t,
            action,
            replicas_after: live,
        });
        self.replicas_min = self.replicas_min.min(self.replicas.len());
        self.replicas_max = self.replicas_max.max(self.replicas.len());
    }

    fn run_loop(&mut self) {
        loop {
            self.settle_transitions();
            let drained = self.next_req >= self.requests.len()
                && self.queue.is_empty()
                && self.replicas.iter().all(|r| r.batcher.is_idle());
            if drained {
                break;
            }
            let mut tn = f64::INFINITY;
            if let Some(r) = self.requests.get(self.next_req) {
                tn = tn.min(r.arrival_s);
            }
            for r in &self.replicas {
                if let Some(x) = r.next_tick {
                    tn = tn.min(x);
                }
                if r.ready_at > self.t {
                    tn = tn.min(r.ready_at);
                }
            }
            if self.scaler.is_some() {
                tn = tn.min(self.next_scale_t);
            }
            assert!(tn.is_finite(), "serving engine stalled at t={}", self.t);
            self.advance_energy(tn);
            self.t = tn;
            while self
                .requests
                .get(self.next_req)
                .is_some_and(|r| r.arrival_s <= self.t)
            {
                let r = &self.requests[self.next_req];
                let token = self.orch.submit_external(self.cfg.model.name, r.arrival_s);
                self.queue.push_back((self.next_req, token));
                self.next_req += 1;
            }
            for i in 0..self.replicas.len() {
                if self.replicas[i].next_tick.is_some_and(|x| x <= self.t) {
                    self.run_iteration(i);
                }
            }
            self.settle_transitions();
            self.feed();
            if self.next_scale_t <= self.t {
                if let Some(sc) = self.scaler.as_mut() {
                    let snap = self.snapshot();
                    let slo_s = self.cfg.slo.p99_s();
                    let action = sc.decide(slo_s, &snap);
                    self.next_scale_t += sc.knobs.interval_s;
                    self.apply_action(action);
                }
            }
        }
    }

    fn report(&self) -> ServeReport {
        let cfg = self.cfg;
        let duration_s = self.t.max(1e-9);
        let completed = self.slo.completed();
        let within_slo = self.slo.within_slo();
        let latency = self.slo.attained();
        let count = |a: ScaleAction| self.events.iter().filter(|e| e.action == a).count();
        let promotions = count(ScaleAction::PromoteProfile);
        let demotions = count(ScaleAction::DemoteProfile);
        ServeReport {
            label: cfg.label.to_string(),
            gpu: cfg.gpu.name.clone(),
            seed: cfg.seed,
            slo: cfg.slo,
            n_requests: self.requests.len(),
            completed,
            within_slo,
            duration_s,
            sustained_rps: within_slo as f64 / duration_s,
            latency,
            slo_margin_ms: self.slo.margin_ms(),
            energy_j: self.energy_j,
            j_per_request: self.energy_j / completed.max(1) as f64,
            mean_busy_gpcs: self.gpc_integral / duration_s,
            mem_utilization: self.mem_integral / (cfg.gpu.total_mem_gb * duration_s),
            kv_alerts: self.kv_alerts,
            scale_ups: count(ScaleAction::AddReplica) + promotions,
            scale_downs: count(ScaleAction::RemoveReplica) + demotions,
            promotions,
            demotions,
            replicas_min: self.replicas_min,
            replicas_max: self.replicas_max,
            reconfig_time_s: self.reconfig_time_s,
            events: self.events.clone(),
        }
    }
}

/// Schema tag of the serving head-to-head trajectory row.
pub const SERVING_BENCH_SCHEMA: &str = "migm.bench.serving.v1";

fn arm_json(r: &ServeReport) -> Json {
    Json::obj(vec![
        ("label", Json::str(r.label.clone())),
        ("sustained_rps", Json::num(r.sustained_rps)),
        ("within_slo", Json::num(r.within_slo as f64)),
        ("p99_turnaround_s", Json::num(r.latency.p99_turnaround_s)),
        ("slo_margin_ms", Json::num(r.slo_margin_ms)),
        ("energy_j", Json::num(r.energy_j)),
        ("j_per_request", Json::num(r.j_per_request)),
        ("scale_ups", Json::num(r.scale_ups as f64)),
        ("scale_downs", Json::num(r.scale_downs as f64)),
    ])
}

/// The autoscaler-vs-static head-to-head as a perf-trajectory row.
/// Both ratios are static ÷ autoscaled where lower-is-better
/// (J/request) and autoscaled ÷ static where higher-is-better (RPS at
/// SLO), so **> 1.0 always means the autoscaler wins**.
pub fn serving_bench_row(
    bench: &str,
    n_requests: usize,
    auto: &ServeReport,
    fixed: &ServeReport,
) -> Json {
    Json::obj(vec![
        ("schema", Json::str(SERVING_BENCH_SCHEMA)),
        ("bench", Json::str(bench)),
        ("n_requests", Json::num(n_requests as f64)),
        ("autoscaled", arm_json(auto)),
        ("static", arm_json(fixed)),
        (
            "rps_at_slo_ratio",
            Json::num(auto.sustained_rps / fixed.sustained_rps.max(1e-12)),
        ),
        (
            "j_per_request_ratio",
            Json::num(fixed.j_per_request / auto.j_per_request.max(1e-12)),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wave_model_step_latency() {
        let m = ModelProfile::qwen2_7b();
        assert_eq!(m.step_s(2), 0.03); // full speed
        assert_eq!(m.step_s(1), 0.06); // eco: two waves
        assert_eq!(m.step_s(7), 0.03); // extra GPCs don't help one model
    }

    #[test]
    fn smoke_run_completes_every_request_within_the_day() {
        let r = run(&ServeConfig::smoke(7));
        assert_eq!(r.n_requests, 240);
        assert_eq!(r.completed, 240);
        assert!(r.within_slo > 0 && r.within_slo <= r.completed);
        assert!(r.sustained_rps > 0.0);
        assert!(r.duration_s > 0.0 && r.energy_j > 0.0);
        assert!(r.latency.p99_turnaround_s > 0.0);
        assert!(r.mem_utilization > 0.0 && r.mem_utilization < 1.0);
        // the external ledger saw every request
        assert!(r.j_per_request > 0.0);
    }

    #[test]
    fn power_model_routes_through_serve_energy_without_touching_scheduling() {
        use crate::power::{Calibration, PowerModel};
        let legacy = run(&ServeConfig::smoke(7));
        // SliceProportional collapses to the same linear whole-GPU
        // curve, so its report pins the Legacy bytes exactly.
        let mut cfg = ServeConfig::smoke(7);
        cfg.gpu = cfg.gpu.clone().with_power_model(PowerModel::SliceProportional);
        let slice = run(&cfg);
        assert_eq!(legacy.to_json().to_string(), slice.to_json().to_string());
        // Measured calibration bends the curve: request flow and
        // timing stay bit-identical, only the energy integral moves.
        let mut cfg = ServeConfig::smoke(7);
        let cal = Calibration::default_for(&cfg.gpu);
        cfg.gpu = cfg.gpu.clone().with_power_model(PowerModel::Measured(cal));
        let measured = run(&cfg);
        assert_eq!(legacy.completed, measured.completed);
        assert_eq!(legacy.within_slo, measured.within_slo);
        assert_eq!(legacy.duration_s.to_bits(), measured.duration_s.to_bits());
        assert_ne!(legacy.energy_j.to_bits(), measured.energy_j.to_bits());
    }

    #[test]
    fn serve_reports_are_byte_identical_per_seed() {
        let a = run(&ServeConfig::smoke(7)).to_json().to_string();
        let b = run(&ServeConfig::smoke(7)).to_json().to_string();
        let c = run(&ServeConfig::smoke(8)).to_json().to_string();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.contains("\"schema\":\"migm.serve.report.v1\""));
    }

    /// The acceptance pin: over a burst-then-sparse-tail trace the
    /// autoscaler must change replica count AND MIG profile at least
    /// once in each direction.
    #[test]
    fn autoscaler_scales_both_directions_including_profiles() {
        let mut arrivals: Vec<f64> = (0..80).map(|i| i as f64 * 0.05).collect();
        arrivals.extend((0..140).map(|i| 40.0 + i as f64 * 6.0));
        let mut cfg = ServeConfig::diurnal(220, 5);
        cfg.traffic = TrafficConfig::Replay { arrivals };
        cfg.autoscaler = Some(AutoscalerKnobs::fast(2.0, 5.0));
        let r = run(&cfg);
        assert_eq!(r.completed, 220);
        assert!(
            r.promotions >= 1,
            "burst must promote the eco replica: {r:?}"
        );
        assert!(
            r.scale_ups > r.promotions,
            "burst must also add replicas: {r:?}"
        );
        assert!(
            r.scale_downs > r.demotions,
            "sparse tail must remove replicas: {r:?}"
        );
        assert!(
            r.demotions >= 1,
            "idle tail must demote the last replica: {r:?}"
        );
        assert!(r.replicas_max > r.replicas_min);
        // events carry the same story, in time order
        assert!(r.events.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn static_arm_never_scales() {
        let mut cfg = ServeConfig::diurnal(120, 3).static_fast(2);
        cfg.label = "serve-static";
        let r = run(&cfg);
        assert_eq!(r.completed, 120);
        assert_eq!(r.scale_ups + r.scale_downs, 0);
        assert_eq!(r.replicas_min, 2);
        assert_eq!(r.replicas_max, 2);
        assert!(r.events.is_empty());
    }

    #[test]
    fn serving_bench_row_pins_fields() {
        let auto = run(&ServeConfig::smoke(7));
        let fixed = run(&ServeConfig::diurnal(240, 7).static_fast(1));
        let row = serving_bench_row("serve_head_to_head", 240, &auto, &fixed);
        let text = row.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed.get("schema").as_str().unwrap(),
            "migm.bench.serving.v1"
        );
        assert_eq!(parsed.get("n_requests").as_f64().unwrap(), 240.0);
        for arm in ["autoscaled", "static"] {
            let a = parsed.get(arm);
            for key in [
                "sustained_rps",
                "within_slo",
                "p99_turnaround_s",
                "slo_margin_ms",
                "energy_j",
                "j_per_request",
                "scale_ups",
                "scale_downs",
            ] {
                assert!(a.get(key).as_f64().is_some(), "{arm}.{key}");
            }
        }
        assert!(parsed.get("rps_at_slo_ratio").as_f64().unwrap() > 0.0);
        assert!(parsed.get("j_per_request_ratio").as_f64().unwrap() > 0.0);
    }
}
