//! Continuous batching on decode: requests join and leave a replica's
//! in-flight batch at iteration boundaries. KV-cache accounting is
//! kept in **integer tokens** (so an eviction restores the ledger
//! bit-for-bit — no float residue), and admission is double-gated:
//!
//! 1. capacity — the new request's full reservation (prompt + decode
//!    tokens) must fit the replica's KV budget next to what's already
//!    reserved;
//! 2. confidence — while the batch is non-empty, the replica's
//!    [`MemoryBelief`](crate::estimator::MemoryBelief) hi-band must
//!    sit under the memory budget. The band is refined from the
//!    observation series via `apply_external_fit`, so a *projected*
//!    over-budget trend pauses admission before reality catches up —
//!    the gate respects confidence bands, not point estimates. An
//!    idle batch admits unconditionally (reality is weights-only), so
//!    a stale high band can never deadlock an empty replica.

use crate::estimator::{BeliefId, BeliefLedger};
use crate::predictor::Observation;
use crate::serving::traffic::Request;

/// One occupied batch slot: a request mid-flight.
#[derive(Debug, Clone)]
pub struct SlotState {
    /// Orchestrator external-ledger token (latency accounting).
    pub token: u64,
    /// The request occupying this slot.
    pub req_id: u64,
    /// Request arrival time, s.
    pub arrival_s: f64,
    /// Admission time (start of service).
    pub start_s: f64,
    /// Prompt tokens not yet prefilled.
    pub prompt_left: u32,
    /// Decode tokens emitted so far.
    pub decode_done: u32,
    /// Decode tokens the request asked for.
    pub decode_target: u32,
    /// KV tokens materialized so far.
    pub used_tokens: u64,
    /// KV tokens reserved at admission (prompt + decode).
    pub reserved_tokens: u64,
}

/// Per-replica continuous batcher.
#[derive(Debug, Clone)]
pub struct Batcher {
    /// The replica's KV belief in the orchestrator's ledger.
    pub belief: BeliefId,
    slots: Vec<Option<SlotState>>,
    reserved_tokens: u64,
    used_tokens: u64,
    budget_tokens: u64,
    weights_gb: f64,
    mem_budget_gb: f64,
    kv_gb_per_token: f64,
}

impl Batcher {
    /// Batcher with `n_slots` slots and a KV budget derived from the
    /// replica's memory minus resident weights.
    pub fn new(
        belief: BeliefId,
        n_slots: usize,
        mem_budget_gb: f64,
        weights_gb: f64,
        kv_gb_per_token: f64,
    ) -> Batcher {
        assert!(n_slots > 0 && kv_gb_per_token > 0.0);
        let budget_tokens =
            ((mem_budget_gb - weights_gb).max(0.0) / kv_gb_per_token).floor() as u64;
        Batcher {
            belief,
            slots: vec![None; n_slots],
            reserved_tokens: 0,
            used_tokens: 0,
            budget_tokens,
            weights_gb,
            mem_budget_gb,
            kv_gb_per_token,
        }
    }

    /// Total batch slots.
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Occupied batch slots.
    pub fn busy_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when no slot is occupied.
    pub fn is_idle(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// KV tokens reserved at admission across all slots.
    pub fn reserved_tokens(&self) -> u64 {
        self.reserved_tokens
    }

    /// KV tokens materialized across all slots.
    pub fn used_tokens(&self) -> u64 {
        self.used_tokens
    }

    /// KV-token capacity after resident weights.
    pub fn budget_tokens(&self) -> u64 {
        self.budget_tokens
    }

    /// Physical footprint right now: weights + materialized KV.
    pub fn used_gb(&self) -> f64 {
        self.weights_gb + self.used_tokens as f64 * self.kv_gb_per_token
    }

    /// The double admission gate (see module docs).
    pub fn can_admit(&self, ledger: &BeliefLedger, req: &Request) -> bool {
        self.slots.iter().any(|s| s.is_none())
            && self.reserved_tokens + req.total_tokens() <= self.budget_tokens
            && (self.is_idle()
                || ledger.get(self.belief).upper_bound_gb() <= self.mem_budget_gb + 1e-9)
    }

    /// Admit `req` into a free slot if both gates pass. Returns true
    /// on admission.
    pub fn admit(&mut self, ledger: &BeliefLedger, req: &Request, token: u64, now_s: f64) -> bool {
        if !self.can_admit(ledger, req) {
            return false;
        }
        let slot = self
            .slots
            .iter_mut()
            .find(|s| s.is_none())
            .expect("can_admit checked a free slot");
        *slot = Some(SlotState {
            token,
            req_id: req.id,
            arrival_s: req.arrival_s,
            start_s: now_s,
            prompt_left: req.prompt_tokens,
            decode_done: 0,
            decode_target: req.decode_tokens,
            used_tokens: 0,
            reserved_tokens: req.total_tokens(),
        });
        self.reserved_tokens += req.total_tokens();
        true
    }

    /// One batch iteration: every occupied slot absorbs a prefill
    /// chunk or decodes one token; finished requests are evicted and
    /// returned (their KV reservation restored exactly — integer
    /// tokens, so `reserve + use − evict` is lossless).
    pub fn step(&mut self, prefill_chunk: u32) -> Vec<SlotState> {
        let mut done = Vec::new();
        for slot in &mut self.slots {
            let Some(s) = slot else { continue };
            if s.prompt_left > 0 {
                let absorbed = s.prompt_left.min(prefill_chunk);
                s.prompt_left -= absorbed;
                s.used_tokens += absorbed as u64;
                self.used_tokens += absorbed as u64;
            } else {
                s.decode_done += 1;
                s.used_tokens += 1;
                self.used_tokens += 1;
                if s.decode_done >= s.decode_target {
                    let finished = slot.take().expect("slot occupied");
                    self.used_tokens -= finished.used_tokens;
                    self.reserved_tokens -= finished.reserved_tokens;
                    done.push(finished);
                }
            }
        }
        done
    }

    /// Push the current physical footprint into the replica's belief
    /// (the same `observe_external` path the PJRT server uses for KV
    /// tracking).
    pub fn observe(&self, ledger: &mut BeliefLedger) {
        let used = self.used_gb();
        ledger.observe_external(
            self.belief,
            Observation {
                req_mem_gb: used,
                reuse_ratio: 1.0,
            },
            used,
        );
    }

    /// Retarget the KV budget after a MIG profile swap. Only legal on
    /// an idle batch — a swap drains the replica first.
    pub fn rebudget(&mut self, mem_budget_gb: f64) {
        assert!(self.is_idle(), "rebudget requires a drained batch");
        self.mem_budget_gb = mem_budget_gb;
        self.budget_tokens =
            ((mem_budget_gb - self.weights_gb).max(0.0) / self.kv_gb_per_token).floor() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::{BeliefConfig, Estimate};
    use crate::predictor::host::fit_one;
    use crate::predictor::Z_99;
    use crate::util::Rng;

    fn req(id: u64, prompt: u32, decode: u32) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt_tokens: prompt,
            decode_tokens: decode,
        }
    }

    fn ledger_with_belief() -> (BeliefLedger, BeliefId) {
        let mut ledger = BeliefLedger::new(BeliefConfig::new(false));
        let id = ledger.register(Estimate::unknown_upfront(1), 0.0);
        (ledger, id)
    }

    #[test]
    fn admission_respects_slot_and_token_capacity() {
        let (ledger, id) = ledger_with_belief();
        // budget: (1.0 - 0.0) / 0.001 = 1000 tokens, 2 slots
        let mut b = Batcher::new(id, 2, 1.0, 0.0, 0.001);
        assert_eq!(b.budget_tokens(), 1000);
        assert!(b.admit(&ledger, &req(0, 300, 100), 0, 0.0));
        assert!(b.admit(&ledger, &req(1, 300, 100), 1, 0.0));
        // no free slot left
        assert!(!b.admit(&ledger, &req(2, 10, 10), 2, 0.0));
        let mut one = Batcher::new(id, 8, 1.0, 0.0, 0.001);
        assert!(one.admit(&ledger, &req(0, 600, 300), 0, 0.0));
        // 900 reserved; 200 more would blow the 1000-token budget
        assert!(!one.admit(&ledger, &req(1, 100, 100), 1, 0.0));
        assert!(one.admit(&ledger, &req(2, 50, 50), 2, 0.0));
    }

    #[test]
    fn hi_band_over_budget_pauses_admission_until_idle() {
        let (mut ledger, id) = ledger_with_belief();
        let mut b = Batcher::new(id, 4, 10.0, 1.0, 0.001);
        assert!(b.admit(&ledger, &req(0, 64, 64), 0, 0.0));
        // Feed a steep growth series and fit it: the projected band
        // top lands far above the 10 GB budget.
        for i in 0..32 {
            ledger.observe_external(
                id,
                Observation {
                    req_mem_gb: 1.0 + 0.4 * i as f64,
                    reuse_ratio: 1.0,
                },
                1.0 + 0.4 * i as f64,
            );
        }
        let (m, r) = ledger.get(id).external_series().unwrap();
        let stats = fit_one(m, r, 64.0, Z_99);
        ledger.apply_external_fit(id, &stats);
        assert!(ledger.get(id).upper_bound_gb() > 10.0);
        // Non-empty batch + over-budget band: the gate holds even
        // though slots and tokens are available.
        assert!(!b.can_admit(&ledger, &req(1, 8, 8)));
        // Drain the batch: an idle replica admits again (weights-only
        // reality), so the stale band cannot deadlock it.
        while !b.is_idle() {
            b.step(64);
        }
        assert!(b.can_admit(&ledger, &req(1, 8, 8)));
    }

    #[test]
    fn eviction_restores_token_accounting_exactly() {
        // Property: any admit/step interleaving ends with zeroed
        // counters once all requests finish — integer-token
        // accounting, so the check is equality, not tolerance.
        let (mut ledger, id) = ledger_with_belief();
        let mut b = Batcher::new(id, 6, 4.0, 1.0, 0.0005);
        let mut rng = Rng::new(42);
        let mut next_id = 0u64;
        let mut admitted = 0usize;
        let mut completed = 0usize;
        for _ in 0..400 {
            if rng.f64() < 0.4 {
                let r = req(next_id, 16 + rng.below(64) as u32, 4 + rng.below(24) as u32);
                if b.admit(&ledger, &r, next_id, 0.0) {
                    admitted += 1;
                }
                next_id += 1;
            }
            completed += b.step(32).len();
            b.observe(&mut ledger);
            assert!(b.reserved_tokens() <= b.budget_tokens());
            assert!(b.used_tokens() <= b.reserved_tokens());
        }
        while !b.is_idle() {
            completed += b.step(32).len();
        }
        assert!(admitted > 10, "exercised {admitted} admissions");
        assert_eq!(completed, admitted);
        assert_eq!(b.reserved_tokens(), 0);
        assert_eq!(b.used_tokens(), 0);
        // The observation path reported every peak to the ledger.
        assert!(ledger.get(id).observed_peak_gb() > 1.0);
    }

    #[test]
    fn prefill_then_decode_counts_iterations() {
        let (ledger, id) = ledger_with_belief();
        let mut b = Batcher::new(id, 1, 10.0, 0.0, 0.001);
        assert!(b.admit(&ledger, &req(0, 100, 3), 7, 1.5));
        // prompt 100 at chunk 64 -> 2 prefill iterations, then 3 decode
        let mut iters = 0;
        while !b.is_idle() {
            let done = b.step(64);
            iters += 1;
            if let Some(s) = done.first() {
                assert_eq!(s.token, 7);
                assert_eq!(s.used_tokens, 103);
                assert_eq!(s.start_s, 1.5);
            }
        }
        assert_eq!(iters, 5);
    }

    #[test]
    fn rebudget_rescales_token_budget() {
        let (_, id) = ledger_with_belief();
        let mut b = Batcher::new(id, 2, 3.0, 1.0, 0.001);
        assert_eq!(b.budget_tokens(), 2000);
        b.rebudget(13.0);
        assert_eq!(b.budget_tokens(), 12000);
    }
}
