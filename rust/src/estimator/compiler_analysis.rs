//! CASE-style compile-time analysis stand-in (paper §4.3, ref [4]).
//!
//! The paper's compiler pass analyzes scientific CUDA workloads and
//! emits, per job, its device-memory footprint and compute requirement
//! (warps). Without nvcc/CUDA we reproduce the *interface*: a workload
//! ships a [`KernelResource`] descriptor (buffer declarations + launch
//! geometry — exactly what the compiler pass derives from the source),
//! and the analyzer folds that into the `(mem_gb, gpcs)` tuple the
//! scheduler consumes, including the paper's warp-folding optimization.

use super::{Estimate, EstimationMethod};

/// A100 SMs per GPC (108 SMs / 7 GPCs, rounded to the MIG slice value).
pub const SMS_PER_GPC: u32 = 14;
/// Maximum resident warps per SM on Ampere.
pub const WARPS_PER_SM: u32 = 64;

/// One device buffer the kernel allocates.
#[derive(Debug, Clone)]
pub struct BufferDecl {
    /// Buffer name (diagnostics only).
    pub name: String,
    /// Element count.
    pub elems: u64,
    /// Bytes per element.
    pub elem_bytes: u32,
    /// Allocation multiplicity (double buffering, per-stream copies...).
    pub copies: u32,
}

/// Kernel resource descriptor — the compiler pass's output.
#[derive(Debug, Clone)]
pub struct KernelResource {
    /// Kernel name (diagnostics only).
    pub name: String,
    /// Device buffers the kernel allocates.
    pub buffers: Vec<BufferDecl>,
    /// Launch block size.
    pub threads_per_block: u32,
    /// Launch grid size.
    pub blocks: u64,
    /// Fixed runtime overhead (CUDA context etc.), GB.
    pub context_gb: f64,
}

/// Analysis result for one workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadAnalysis {
    /// Estimated peak device memory, GB.
    pub mem_gb: f64,
    /// Raw warp demand of the launch.
    pub warps: u64,
    /// GPC demand before folding.
    pub gpcs_raw: u8,
    /// GPC demand after warp folding against `fold_limit` GPCs.
    pub gpcs_folded: u8,
}

/// Warp folding (paper §4.3): find the smallest GPC count `c' <= c`
/// that preserves the number of execution "time steps"
/// `ceil(demand / c)`. Freed GPCs can host other workloads with no
/// slowdown for this one.
pub fn fold_warps(demand_gpcs: u8, available_gpcs: u8) -> u8 {
    if demand_gpcs == 0 {
        return 1;
    }
    let c = available_gpcs.max(1);
    let steps = demand_gpcs.div_ceil(c);
    // smallest c' with ceil(d / c') == steps
    let mut best = c;
    for cand in 1..=c {
        if demand_gpcs.div_ceil(cand) == steps {
            best = cand;
            break;
        }
    }
    best
}

/// Analyze a kernel descriptor into the scheduler's estimate tuple.
pub fn analyze(k: &KernelResource, total_gpcs: u8) -> WorkloadAnalysis {
    let bytes: u64 = k
        .buffers
        .iter()
        .map(|b| b.elems * b.elem_bytes as u64 * b.copies as u64)
        .sum();
    let mem_gb = bytes as f64 / 1e9 + k.context_gb;
    let warps = k.blocks * (k.threads_per_block as u64).div_ceil(32);
    let warps_per_gpc = (SMS_PER_GPC * WARPS_PER_SM) as u64;
    let gpcs_raw = warps
        .div_ceil(warps_per_gpc)
        .min(total_gpcs as u64)
        .max(1) as u8;
    WorkloadAnalysis {
        mem_gb,
        warps,
        gpcs_raw,
        gpcs_folded: fold_warps(gpcs_raw, total_gpcs),
    }
}

impl WorkloadAnalysis {
    /// The pipeline estimate: static analysis is exact, so the band is
    /// degenerate (lo = point = hi).
    pub fn to_estimate(self) -> Estimate {
        Estimate::exact(
            self.mem_gb,
            self.gpcs_folded,
            EstimationMethod::CompilerAnalysis,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(elems: u64, blocks: u64, tpb: u32) -> KernelResource {
        KernelResource {
            name: "t".into(),
            buffers: vec![BufferDecl {
                name: "a".into(),
                elems,
                elem_bytes: 4,
                copies: 1,
            }],
            threads_per_block: tpb,
            blocks,
            context_gb: 0.3,
        }
    }

    #[test]
    fn footprint_sums_buffers_and_context() {
        let mut kr = k(250_000_000, 1, 32); // 1 GB buffer
        kr.buffers.push(BufferDecl {
            name: "b".into(),
            elems: 125_000_000,
            elem_bytes: 4,
            copies: 2, // 1 GB total
        });
        let a = analyze(&kr, 7);
        assert!((a.mem_gb - 2.3).abs() < 1e-6, "{}", a.mem_gb);
    }

    #[test]
    fn tiny_launch_needs_one_gpc() {
        let a = analyze(&k(1000, 10, 64), 7);
        assert_eq!(a.gpcs_raw, 1);
        assert_eq!(a.gpcs_folded, 1);
    }

    #[test]
    fn huge_launch_saturates_gpu() {
        let a = analyze(&k(1000, 1_000_000, 1024), 7);
        assert_eq!(a.gpcs_raw, 7);
    }

    #[test]
    fn warp_folding_preserves_timesteps() {
        // paper's example: demand 120 SMs on a 100-SM GPU -> 2 steps;
        // 60 SMs also gives 2 steps. In GPC units: demand 6 of 5
        // available -> 2 steps; folding should give 3 (ceil(6/3)=2).
        assert_eq!(fold_warps(6, 5), 3);
        // demand fits: ceil(4/7)=1 -> smallest c' with 1 step is 4.
        assert_eq!(fold_warps(4, 7), 4);
        // exact fit stays.
        assert_eq!(fold_warps(7, 7), 7);
        // degenerate demand.
        assert_eq!(fold_warps(0, 7), 1);
    }

    #[test]
    fn folding_never_increases_steps() {
        for d in 1..=14u8 {
            for c in 1..=7u8 {
                let f = fold_warps(d, c);
                assert!(f >= 1 && f <= c);
                assert_eq!(d.div_ceil(f), d.div_ceil(c), "d={d} c={c} f={f}");
            }
        }
    }
}
