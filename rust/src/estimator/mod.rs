//! Memory & compute estimation for incoming jobs (paper §4.3) — the
//! **estimation pipeline**.
//!
//! Estimation is a first-class pipeline, not a set of disconnected
//! helpers: every job's a-priori requirement is produced by an
//! [`Estimator`] tier behind one entry point
//! ([`pipeline::EstimationPipeline`], usually via
//! [`pipeline::default_pipeline`]), as a rich [`Estimate`] — a
//! lo/point/hi confidence band plus method provenance and a refinement
//! generation — instead of a write-once scalar. Three tiers, matching
//! the paper's strategy:
//!
//! * [`compiler_analysis`] — CASE-style static analysis for scientific
//!   workloads: derives the device-memory footprint and warp/GPC demand
//!   from a kernel-resource descriptor (the tuple the paper's compiler
//!   pass [4] emits), plus the warp-folding optimization. Exact: the
//!   band is degenerate (lo = point = hi).
//! * [`dnnmem`] — DNNMem-style offline estimation for DNN training
//!   jobs: walks the layer graph and sums weights, gradients, optimizer
//!   state, activations and library workspace. The band's lower edge
//!   strips the allocator-fragmentation slack (the reserved-vs-allocated
//!   gap is the estimate's main uncertainty).
//! * time-series (module [`crate::predictor`]) for workloads whose
//!   memory grows dynamically: the a-priori estimate is the explicit
//!   [`MemoryDemand::Unknown`] state (no sentinel values) — the
//!   scheduler starts those on the smallest slice and the per-job
//!   [`belief::MemoryBelief`] refines the band online from allocator
//!   observations.
//!
//! At runtime each job's current knowledge lives in a
//! [`belief::MemoryBelief`] inside the orchestrator-owned
//! [`belief::BeliefLedger`]; scheduling policies consult beliefs — never
//! the `JobSpec`'s construction-time estimate — for slice selection,
//! fusion width, and predictive-restart decisions.
//!
//! The flat [`MemoryEstimate`] is retained as the legacy surface: the
//! default pipeline reproduces it bit-for-bit ([`Estimate::to_legacy`];
//! proven per paper mix by `pipeline::tests`).

pub mod belief;
pub mod compiler_analysis;
pub mod dnnmem;
pub mod pipeline;
pub mod workspace;

pub use belief::{
    BeliefConfig, BeliefId, BeliefKnobs, BeliefLedger, BeliefSnapshot, MemoryBelief,
    PredictionAccuracy,
};
pub use compiler_analysis::{fold_warps, KernelResource, WorkloadAnalysis};
pub use dnnmem::{DnnEstimate, Layer, ModelDef, Optimizer};
pub use pipeline::{default_pipeline, EstimateInput, EstimationPipeline, Estimator};
pub use workspace::{estimate_workspace_gb, parse_cublas_workspace_config, WorkspacePool};

/// How a job's memory requirement was obtained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimationMethod {
    /// Static/JIT compiler analysis (scientific workloads).
    CompilerAnalysis,
    /// Offline model-size estimation (DNNMem).
    ModelSize,
    /// Unknown upfront; runtime time-series prediction.
    TimeSeries,
}

/// The legacy flat estimate. Kept as the compatibility surface the
/// parity/property tests pin the pipeline against
/// ([`Estimate::to_legacy`]); nothing on the scheduling path consumes
/// it anymore.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryEstimate {
    /// Peak device memory, GB. For `TimeSeries` this is the *initial*
    /// guess (smallest slice) and is refined online.
    pub mem_gb: f64,
    /// Compute demand in GPC units (soft constraint).
    pub compute_gpcs: u8,
    /// Which tier produced the estimate.
    pub method: EstimationMethod,
}

/// A memory requirement with explicit uncertainty.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemoryDemand {
    /// Unknown upfront (the time-series tier before any runtime
    /// evidence): the scheduler starts on the smallest slice and grows
    /// on demand. This replaces the old `mem_gb <= 0.0` sentinel.
    Unknown,
    /// A confidence band, GB: `lo_gb <= point_gb <= hi_gb`. The point
    /// drives placement (it is the legacy `mem_gb`); the band carries
    /// the estimator's uncertainty for consumers that want it
    /// (tuner state, reports, future RL partitioners).
    Band {
        /// Lower edge of the band, GB.
        lo_gb: f64,
        /// Placement-driving point value (the legacy `mem_gb`), GB.
        point_gb: f64,
        /// Upper edge of the band, GB.
        hi_gb: f64,
    },
}

/// A rich estimate: confidence band + provenance + refinement
/// generation. Produced by [`Estimator`] tiers at job construction and
/// refined at runtime through [`belief::MemoryBelief`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Estimate {
    /// The memory requirement with its uncertainty band.
    pub demand: MemoryDemand,
    /// Compute demand in GPC units (soft constraint).
    pub compute_gpcs: u8,
    /// Which tier produced the estimate.
    pub method: EstimationMethod,
    /// Refinement generation: 0 for the a-priori estimate, incremented
    /// by every runtime refinement (OOM bump, converged prediction,
    /// external fit). Strictly monotone per belief.
    pub generation: u32,
}

impl Estimate {
    /// An exact (degenerate-band) estimate.
    pub fn exact(mem_gb: f64, compute_gpcs: u8, method: EstimationMethod) -> Estimate {
        Estimate::banded(mem_gb, mem_gb, mem_gb, compute_gpcs, method)
    }

    /// A banded estimate; the band is clamped to `lo <= point <= hi`.
    pub fn banded(
        lo_gb: f64,
        point_gb: f64,
        hi_gb: f64,
        compute_gpcs: u8,
        method: EstimationMethod,
    ) -> Estimate {
        Estimate {
            demand: MemoryDemand::Band {
                lo_gb: lo_gb.min(point_gb),
                point_gb,
                hi_gb: hi_gb.max(point_gb),
            },
            compute_gpcs,
            method,
            generation: 0,
        }
    }

    /// The explicit unknown-upfront state of the time-series tier.
    pub fn unknown_upfront(compute_gpcs: u8) -> Estimate {
        Estimate {
            demand: MemoryDemand::Unknown,
            compute_gpcs,
            method: EstimationMethod::TimeSeries,
            generation: 0,
        }
    }

    /// True for the unknown-upfront (time-series) state.
    pub fn is_unknown(&self) -> bool {
        matches!(self.demand, MemoryDemand::Unknown)
    }

    /// The placement-driving point value (the legacy `mem_gb`); 0.0 in
    /// the unknown state, mirroring the historical sentinel at the one
    /// boundary ([`to_legacy`](Self::to_legacy)) that still speaks it.
    pub fn point_gb(&self) -> f64 {
        match self.demand {
            MemoryDemand::Unknown => 0.0,
            MemoryDemand::Band { point_gb, .. } => point_gb,
        }
    }

    /// Upper edge of the band (0.0 when unknown).
    pub fn hi_gb(&self) -> f64 {
        match self.demand {
            MemoryDemand::Unknown => 0.0,
            MemoryDemand::Band { hi_gb, .. } => hi_gb,
        }
    }

    /// Lower edge of the band (0.0 when unknown).
    pub fn lo_gb(&self) -> f64 {
        match self.demand {
            MemoryDemand::Unknown => 0.0,
            MemoryDemand::Band { lo_gb, .. } => lo_gb,
        }
    }

    /// A copy whose point (and degenerate band) is `point_gb`, keeping
    /// provenance and bumping the generation. The refinement edge used
    /// by OOM bumps and the legacy golden loops.
    pub fn with_point(self, point_gb: f64) -> Estimate {
        self.refined(MemoryDemand::Band {
            lo_gb: point_gb,
            point_gb,
            hi_gb: point_gb,
        })
    }

    /// A copy with a new demand and the generation incremented.
    pub fn refined(self, demand: MemoryDemand) -> Estimate {
        Estimate {
            demand,
            generation: self.generation + 1,
            ..self
        }
    }

    /// Collapse to the legacy flat estimate (bit-for-bit what the old
    /// constructors produced; the unknown state maps back to the 0.0
    /// sentinel).
    pub fn to_legacy(&self) -> MemoryEstimate {
        MemoryEstimate {
            mem_gb: self.point_gb(),
            compute_gpcs: self.compute_gpcs,
            method: self.method,
        }
    }

    /// Bit-exact snapshot form (checkpoint layer; see `util::snap`).
    pub fn to_snap_json(&self) -> crate::util::Json {
        use crate::util::snap::f64_to_json;
        use crate::util::Json;
        let demand = match self.demand {
            MemoryDemand::Unknown => Json::Null,
            MemoryDemand::Band {
                lo_gb,
                point_gb,
                hi_gb,
            } => Json::obj(vec![
                ("lo_gb", f64_to_json(lo_gb)),
                ("point_gb", f64_to_json(point_gb)),
                ("hi_gb", f64_to_json(hi_gb)),
            ]),
        };
        Json::obj(vec![
            ("demand", demand),
            ("compute_gpcs", Json::num(self.compute_gpcs as f64)),
            ("method", Json::str(self.method.snap_tag())),
            ("generation", Json::num(self.generation as f64)),
        ])
    }

    /// Inverse of [`Self::to_snap_json`].
    pub fn from_snap_json(j: &crate::util::Json) -> anyhow::Result<Estimate> {
        use crate::util::snap::{f64_from_json, usize_from_json};
        let d = j.get("demand");
        let demand = if d.is_null() {
            MemoryDemand::Unknown
        } else {
            MemoryDemand::Band {
                lo_gb: f64_from_json(d.get("lo_gb"))?,
                point_gb: f64_from_json(d.get("point_gb"))?,
                hi_gb: f64_from_json(d.get("hi_gb"))?,
            }
        };
        Ok(Estimate {
            demand,
            compute_gpcs: usize_from_json(j.get("compute_gpcs"))? as u8,
            method: EstimationMethod::from_snap_tag(
                j.get("method").as_str().unwrap_or_default(),
            )?,
            generation: usize_from_json(j.get("generation"))? as u32,
        })
    }
}

impl EstimationMethod {
    /// Stable snapshot tag.
    pub fn snap_tag(&self) -> &'static str {
        match self {
            EstimationMethod::CompilerAnalysis => "compiler-analysis",
            EstimationMethod::ModelSize => "model-size",
            EstimationMethod::TimeSeries => "time-series",
        }
    }

    /// Inverse of [`Self::snap_tag`].
    pub fn from_snap_tag(tag: &str) -> anyhow::Result<EstimationMethod> {
        match tag {
            "compiler-analysis" => Ok(EstimationMethod::CompilerAnalysis),
            "model-size" => Ok(EstimationMethod::ModelSize),
            "time-series" => Ok(EstimationMethod::TimeSeries),
            other => anyhow::bail!("unknown estimation-method tag {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn band_is_clamped_around_the_point() {
        let e = Estimate::banded(9.0, 8.0, 7.0, 2, EstimationMethod::ModelSize);
        assert_eq!(e.lo_gb(), 8.0);
        assert_eq!(e.point_gb(), 8.0);
        assert_eq!(e.hi_gb(), 8.0);
        let e = Estimate::banded(6.0, 8.0, 10.0, 2, EstimationMethod::ModelSize);
        assert_eq!((e.lo_gb(), e.point_gb(), e.hi_gb()), (6.0, 8.0, 10.0));
    }

    #[test]
    fn unknown_maps_to_the_legacy_sentinel_only_at_the_edge() {
        let e = Estimate::unknown_upfront(2);
        assert!(e.is_unknown());
        assert_eq!(e.method, EstimationMethod::TimeSeries);
        let legacy = e.to_legacy();
        assert_eq!(legacy.mem_gb, 0.0);
        assert_eq!(legacy.method, EstimationMethod::TimeSeries);
    }

    #[test]
    fn refinement_bumps_the_generation() {
        let e = Estimate::unknown_upfront(1);
        assert_eq!(e.generation, 0);
        let r = e.with_point(10.0);
        assert_eq!(r.generation, 1);
        assert!(!r.is_unknown());
        assert_eq!(r.point_gb(), 10.0);
        let r2 = r.with_point(20.0);
        assert_eq!(r2.generation, 2);
        // provenance survives refinement
        assert_eq!(r2.method, EstimationMethod::TimeSeries);
    }

    #[test]
    fn exact_round_trips_to_legacy() {
        let e = Estimate::exact(6.0, 2, EstimationMethod::CompilerAnalysis);
        let l = e.to_legacy();
        assert_eq!(l.mem_gb, 6.0);
        assert_eq!(l.compute_gpcs, 2);
        assert_eq!(l.method, EstimationMethod::CompilerAnalysis);
    }

    #[test]
    fn estimate_snap_roundtrips_through_text() {
        use crate::util::Json;
        let cases = [
            Estimate::unknown_upfront(3),
            Estimate::exact(6.25, 2, EstimationMethod::CompilerAnalysis),
            Estimate::banded(4.0, 8.125, 16.5, 7, EstimationMethod::ModelSize)
                .refined(MemoryDemand::Band {
                    lo_gb: 5.0,
                    point_gb: 9.0,
                    hi_gb: 12.0,
                }),
        ];
        for e in cases {
            let text = e.to_snap_json().to_string();
            let back = Estimate::from_snap_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, e);
        }
    }
}
