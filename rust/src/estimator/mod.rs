//! Memory & compute estimation for incoming jobs (paper §4.3).
//!
//! Three tiers, matching the paper's estimation strategy:
//!
//! * [`compiler_analysis`] — CASE-style static analysis for scientific
//!   workloads: derives the device-memory footprint and warp/GPC demand
//!   from a kernel-resource descriptor (the tuple the paper's compiler
//!   pass [4] emits), plus the warp-folding optimization.
//! * [`dnnmem`] — DNNMem-style offline estimation for DNN training
//!   jobs: walks the layer graph and sums weights, gradients, optimizer
//!   state, activations and library workspace.
//! * time-series prediction (module [`crate::predictor`]) for workloads
//!   whose memory grows dynamically; the scheduler starts those on the
//!   smallest slice and relies on prediction/OOM restart.

pub mod compiler_analysis;
pub mod dnnmem;
pub mod workspace;

pub use compiler_analysis::{fold_warps, KernelResource, WorkloadAnalysis};
pub use workspace::{estimate_workspace_gb, parse_cublas_workspace_config, WorkspacePool};
pub use dnnmem::{DnnEstimate, Layer, ModelDef, Optimizer};

/// How a job's memory requirement was obtained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimationMethod {
    /// Static/JIT compiler analysis (scientific workloads).
    CompilerAnalysis,
    /// Offline model-size estimation (DNNMem).
    ModelSize,
    /// Unknown upfront; runtime time-series prediction.
    TimeSeries,
}

/// The estimate consumed by the scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryEstimate {
    /// Peak device memory, GB. For `TimeSeries` this is the *initial*
    /// guess (smallest slice) and is refined online.
    pub mem_gb: f64,
    /// Compute demand in GPC units (soft constraint).
    pub compute_gpcs: u8,
    pub method: EstimationMethod,
}
