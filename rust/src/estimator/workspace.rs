//! Third-party-library workspace estimation (paper §3.2.2).
//!
//! cuDNN/cuBLAS allocate fixed workspace pools on behalf of the model;
//! these do **not** grow with context size, so the predictor must
//! discount them from the time-series fit. The paper infers their size
//! by parsing environment configuration such as
//! `CUBLAS_WORKSPACE_CONFIG=:4096:8,:16:8` (pool-size-KiB : pool-count
//! pairs) and by walking model layers for per-layer cuDNN scratch.

use crate::estimator::dnnmem::{Layer, ModelDef};

/// One workspace pool parsed from `CUBLAS_WORKSPACE_CONFIG`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkspacePool {
    /// Pool buffer size, KiB.
    pub size_kib: u64,
    /// Number of buffers in the pool.
    pub count: u64,
}

impl WorkspacePool {
    /// Total pool footprint in bytes.
    pub fn bytes(&self) -> u64 {
        self.size_kib * 1024 * self.count
    }
}

/// Parse a `CUBLAS_WORKSPACE_CONFIG` value. Format: comma-separated
/// `:<size_kib>:<count>` entries (the leading colon is part of the
/// documented syntax). Unparseable entries are rejected.
pub fn parse_cublas_workspace_config(value: &str) -> Option<Vec<WorkspacePool>> {
    let mut pools = Vec::new();
    for entry in value.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let rest = entry.strip_prefix(':')?;
        let (size, count) = rest.split_once(':')?;
        pools.push(WorkspacePool {
            size_kib: size.trim().parse().ok()?,
            count: count.trim().parse().ok()?,
        });
    }
    Some(pools)
}

/// The CUDA default when the variable is unset (`:4096:2,:16:8` per the
/// cuBLAS documentation for deterministic workspaces).
pub fn default_pools() -> Vec<WorkspacePool> {
    vec![
        WorkspacePool { size_kib: 4096, count: 2 },
        WorkspacePool { size_kib: 16, count: 8 },
    ]
}

/// Per-layer cuDNN scratch (batch-independent part), bytes.
fn layer_scratch_bytes(layer: &Layer) -> u64 {
    match layer {
        // implicit-GEMM algorithm workspace
        Layer::Conv2d { .. } => 64 << 20,
        // cuBLAS GEMM scratch
        Layer::Linear { .. } | Layer::TransformerBlock { .. } => 8 << 20,
        _ => 0,
    }
}

/// Aggregate workspace estimate for a model (paper: "walks through model
/// layers, estimates per-layer workspace sizes, and aggregates them").
/// `env_config` is the raw `CUBLAS_WORKSPACE_CONFIG` value if set.
pub fn estimate_workspace_gb(model: &ModelDef, env_config: Option<&str>) -> f64 {
    let pools = env_config
        .and_then(parse_cublas_workspace_config)
        .unwrap_or_else(default_pools);
    let pool_bytes: u64 = pools.iter().map(|p| p.bytes()).sum();
    // Per-layer scratch is reused across layers of the same kind; take
    // the max conv scratch + max gemm scratch rather than the sum.
    let conv = model
        .layers
        .iter()
        .filter(|l| matches!(l, Layer::Conv2d { .. }))
        .map(layer_scratch_bytes)
        .max()
        .unwrap_or(0);
    let gemm = model
        .layers
        .iter()
        .filter(|l| !matches!(l, Layer::Conv2d { .. }))
        .map(layer_scratch_bytes)
        .max()
        .unwrap_or(0);
    (pool_bytes + conv + gemm) as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::dnnmem;

    #[test]
    fn parses_documented_syntax() {
        let pools = parse_cublas_workspace_config(":4096:8,:16:8").unwrap();
        assert_eq!(
            pools,
            vec![
                WorkspacePool { size_kib: 4096, count: 8 },
                WorkspacePool { size_kib: 16, count: 8 },
            ]
        );
        assert_eq!(pools[0].bytes(), 4096 * 1024 * 8);
    }

    #[test]
    fn rejects_malformed_entries() {
        assert!(parse_cublas_workspace_config("4096:8").is_none());
        assert!(parse_cublas_workspace_config(":x:8").is_none());
        assert!(parse_cublas_workspace_config(":4096").is_none());
    }

    #[test]
    fn empty_config_gives_no_pools() {
        assert_eq!(parse_cublas_workspace_config("").unwrap(), vec![]);
    }

    #[test]
    fn workspace_is_fixed_wrt_batch() {
        // The whole point of §3.2.2: workspace must be batch-independent
        // so it can be excluded from the time-series fit.
        let m = dnnmem::vgg16();
        let a = estimate_workspace_gb(&m, None);
        let b = estimate_workspace_gb(&m, None);
        assert_eq!(a, b);
        assert!(a > 0.05 && a < 1.0, "{a}");
    }

    #[test]
    fn env_override_changes_estimate() {
        let m = dnnmem::bert_base(128);
        let small = estimate_workspace_gb(&m, Some(":16:1"));
        let big = estimate_workspace_gb(&m, Some(":4096:16"));
        assert!(big > small);
    }
}
