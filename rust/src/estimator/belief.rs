//! Per-job memory beliefs: the runtime half of the estimation pipeline.
//!
//! A [`MemoryBelief`] is everything the system currently knows about
//! one job's memory requirement: the refined [`Estimate`] (band +
//! provenance + generation), the peak actually observed so far, the
//! latest converged projection, and — for dynamic workloads with
//! prediction enabled — the live Algorithm-1 [`JobMonitor`]. The
//! [`BeliefLedger`] holds one belief per submitted job and is owned by
//! the scheduling [`Orchestrator`](crate::scheduler::Orchestrator):
//! the simulator *emits* allocator [`Observation`]s (it no longer
//! consumes them internally), the orchestrator routes them into the
//! ledger, and scheduling policies consult `ctx.belief(id)` — never the
//! `JobSpec`'s construction-time estimate — for every placement,
//! fusion, and predictive-restart decision. The serving front-end
//! routes its per-replica KV-growth tracking through the same ledger
//! ([`BeliefLedger::observe_external`] / `apply_external_fit`).
//!
//! Invariants (property-tested below):
//! * a belief's upper bound ([`MemoryBelief::upper_bound_gb`]) never
//!   drops below any peak it has observed;
//! * refinement generations are strictly monotone;
//! * with the default [`BeliefKnobs`], the ledger's convergence
//!   decisions are bit-for-bit those of a bare [`JobMonitor`] with the
//!   paper's `ConvergenceCfg` — which is what keeps the scheduler
//!   parity suite green.

use anyhow::{bail, Result};

use crate::mig::GpuSpec;
use crate::predictor::{
    ConvergenceCfg, FitStats, JobMonitor, Observation, PredictionOutcome, Z_99,
};
use crate::util::Json;
use crate::workloads::{ComputeModel, JobKind, JobSpec};

use super::{Estimate, MemoryDemand};

/// Index of a belief in its ledger. Assigned at submission; carried by
/// `PendingJob`/`JobEvent` through every requeue and restart.
pub type BeliefId = usize;

/// Tunable belief parameters, swept by the [`tuner`](crate::tuner).
/// `Default` reproduces the paper bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BeliefKnobs {
    /// z-score of the prediction confidence band (paper: 2.576 = 99%).
    pub z: f64,
    /// Convergence window: consecutive projections compared for
    /// stability (paper: 3).
    pub window: usize,
    /// Safety margin applied to a converged projection when refining
    /// the demand (`point = peak * (1 + margin)`; paper: 0 — restart
    /// onto the slice the projection itself selects).
    pub safety_margin: f64,
}

impl Default for BeliefKnobs {
    fn default() -> Self {
        BeliefKnobs {
            z: Z_99,
            window: ConvergenceCfg::default().window,
            safety_margin: 0.0,
        }
    }
}

impl BeliefKnobs {
    /// The convergence policy these knobs select.
    pub fn conv_cfg(&self) -> ConvergenceCfg {
        ConvergenceCfg {
            window: self.window,
            z: self.z,
            ..ConvergenceCfg::default()
        }
    }

    /// Serialize for candidate/checkpoint JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("z", Json::num(self.z)),
            ("window", Json::num(self.window as f64)),
            ("safety_margin", Json::num(self.safety_margin)),
        ])
    }

    /// Parse knobs from candidate/checkpoint JSON (missing keys ⇒ defaults).
    pub fn from_json(doc: &Json) -> Result<Self> {
        let mut k = BeliefKnobs::default();
        match doc.get("z") {
            Json::Null => {}
            v => match v.as_f64() {
                Some(x) if x > 0.0 => k.z = x,
                _ => bail!("belief z must be a positive number, got {v}"),
            },
        }
        match doc.get("window") {
            Json::Null => {}
            // as_u64 alone would truncate 2.9 to 2; require a whole number
            v => match v.as_f64() {
                Some(x) if x >= 1.0 && x.fract() == 0.0 => k.window = x as usize,
                _ => bail!("belief window must be a positive integer, got {v}"),
            },
        }
        match doc.get("safety_margin") {
            Json::Null => {}
            v => match v.as_f64() {
                Some(x) if x >= 0.0 => k.safety_margin = x,
                _ => bail!("safety_margin must be a non-negative number, got {v}"),
            },
        }
        Ok(k)
    }
}

/// Ledger-wide configuration: the predictor switch plus the belief
/// knobs. `prediction: false` disables monitors entirely (the paper's
/// no-prediction arms).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BeliefConfig {
    /// Run time-series predictors on iterative jobs.
    pub prediction: bool,
    /// The convergence/restart knobs.
    pub knobs: BeliefKnobs,
}

impl BeliefConfig {
    /// Config with default knobs and the given predictor switch.
    pub fn new(prediction: bool) -> BeliefConfig {
        BeliefConfig {
            prediction,
            knobs: BeliefKnobs::default(),
        }
    }
}

/// Everything currently believed about one job's memory requirement.
#[derive(Debug, Clone)]
pub struct MemoryBelief {
    est: Estimate,
    /// Realized peak the job is known to reach (for report accuracy;
    /// never consulted by scheduling decisions).
    true_peak_gb: f64,
    observed_peak_gb: f64,
    predicted_peak_gb: Option<f64>,
    monitor: Option<JobMonitor>,
    /// External (wall-clock) observation series — the server's
    /// per-replica KV tracking: (req_mem_gb, inv_reuse) per step.
    external: Option<(Vec<f64>, Vec<f64>)>,
}

impl MemoryBelief {
    fn new(est: Estimate, true_peak_gb: f64) -> MemoryBelief {
        MemoryBelief {
            est,
            true_peak_gb,
            observed_peak_gb: 0.0,
            predicted_peak_gb: None,
            monitor: None,
            external: None,
        }
    }

    /// The current refined estimate (band + provenance + generation).
    pub fn estimate(&self) -> &Estimate {
        &self.est
    }

    /// The placement-driving demand (the band's point; 0 when unknown).
    pub fn demand_gb(&self) -> f64 {
        self.est.point_gb()
    }

    /// The job's compute demand in GPC units.
    pub fn compute_gpcs(&self) -> u8 {
        self.est.compute_gpcs
    }

    /// True while the memory requirement is unknown upfront.
    pub fn is_unknown(&self) -> bool {
        self.est.is_unknown()
    }

    /// Refinement generation of the current estimate.
    pub fn generation(&self) -> u32 {
        self.est.generation
    }

    /// The belief's upper bound: never below any observed peak.
    pub fn upper_bound_gb(&self) -> f64 {
        self.est.hi_gb().max(self.observed_peak_gb)
    }

    /// Highest footprint observed at runtime so far, GB.
    pub fn observed_peak_gb(&self) -> f64 {
        self.observed_peak_gb
    }

    /// Latest converged peak projection, if prediction ever converged.
    pub fn predicted_peak_gb(&self) -> Option<f64> {
        self.predicted_peak_gb
    }

    /// Realized peak recorded at registration (report accuracy anchor).
    pub fn true_peak_gb(&self) -> f64 {
        self.true_peak_gb
    }

    /// The live monitor (dynamic jobs with prediction, while running).
    pub fn monitor(&self) -> Option<&JobMonitor> {
        self.monitor.as_ref()
    }

    /// External observation series (server KV tracking), if any.
    pub fn external_series(&self) -> Option<(&[f64], &[f64])> {
        self.external.as_ref().map(|(m, r)| (&m[..], &r[..]))
    }

    /// Replace the band, bumping the generation and clamping the upper
    /// edge so it never drops below the observed peak.
    fn refine_band(&mut self, lo_gb: f64, point_gb: f64, hi_gb: f64) {
        let hi = hi_gb.max(point_gb).max(self.observed_peak_gb);
        self.est = self.est.refined(MemoryDemand::Band {
            lo_gb: lo_gb.min(point_gb),
            point_gb,
            hi_gb: hi,
        });
    }

    /// Bit-exact snapshot form (checkpoint layer): the refined estimate,
    /// observed/predicted peaks, the live Algorithm-1 monitor if any,
    /// and the external KV series.
    pub fn to_snap_json(&self) -> Json {
        use crate::util::snap::{f64_to_json, f64s_to_json};
        Json::obj(vec![
            ("est", self.est.to_snap_json()),
            ("true_peak_gb", f64_to_json(self.true_peak_gb)),
            ("observed_peak_gb", f64_to_json(self.observed_peak_gb)),
            (
                "predicted_peak_gb",
                match self.predicted_peak_gb {
                    Some(p) => f64_to_json(p),
                    None => Json::Null,
                },
            ),
            (
                "monitor",
                match &self.monitor {
                    Some(m) => m.to_snap_json(),
                    None => Json::Null,
                },
            ),
            (
                "external",
                match &self.external {
                    Some((m, r)) => Json::obj(vec![
                        ("req_mem", f64s_to_json(m)),
                        ("inv_reuse", f64s_to_json(r)),
                    ]),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Inverse of [`Self::to_snap_json`].
    pub fn from_snap_json(j: &Json) -> Result<MemoryBelief> {
        use crate::util::snap::{f64_from_json, f64s_from_json};
        let predicted_peak_gb = if j.get("predicted_peak_gb").is_null() {
            None
        } else {
            Some(f64_from_json(j.get("predicted_peak_gb"))?)
        };
        let monitor = if j.get("monitor").is_null() {
            None
        } else {
            Some(JobMonitor::from_snap_json(j.get("monitor"))?)
        };
        let external = if j.get("external").is_null() {
            None
        } else {
            let e = j.get("external");
            Some((
                f64s_from_json(e.get("req_mem"))?,
                f64s_from_json(e.get("inv_reuse"))?,
            ))
        };
        Ok(MemoryBelief {
            est: Estimate::from_snap_json(j.get("est"))?,
            true_peak_gb: f64_from_json(j.get("true_peak_gb"))?,
            observed_peak_gb: f64_from_json(j.get("observed_peak_gb"))?,
            predicted_peak_gb,
            monitor,
            external,
        })
    }
}

/// Aggregate predicted-vs-actual accuracy over a ledger (the `migm
/// report online` error column).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PredictionAccuracy {
    /// Beliefs that received at least one allocator observation.
    pub n_tracked: usize,
    /// Beliefs whose prediction converged at least once.
    pub n_predicted: usize,
    /// Mean |predicted − actual| / actual over converged beliefs.
    pub mean_abs_pct_err: f64,
}

/// One belief per submitted job, owned by the orchestrator.
pub struct BeliefLedger {
    cfg: BeliefConfig,
    conv: ConvergenceCfg,
    beliefs: Vec<MemoryBelief>,
}

impl BeliefLedger {
    /// Empty ledger under `cfg`.
    pub fn new(cfg: BeliefConfig) -> BeliefLedger {
        BeliefLedger {
            cfg,
            conv: cfg.knobs.conv_cfg(),
            beliefs: Vec::new(),
        }
    }

    /// The ledger's configuration.
    pub fn config(&self) -> &BeliefConfig {
        &self.cfg
    }

    /// Number of opened beliefs (one per submitted job).
    pub fn len(&self) -> usize {
        self.beliefs.len()
    }

    /// True when no beliefs have been opened.
    pub fn is_empty(&self) -> bool {
        self.beliefs.is_empty()
    }

    /// Open a belief seeded with a pipeline estimate. `true_peak_gb` is
    /// the realized peak (report accuracy only; 0 if unknown).
    pub fn register(&mut self, est: Estimate, true_peak_gb: f64) -> BeliefId {
        self.beliefs.push(MemoryBelief::new(est, true_peak_gb));
        self.beliefs.len() - 1
    }

    /// The belief for job `id`.
    pub fn get(&self, id: BeliefId) -> &MemoryBelief {
        &self.beliefs[id]
    }

    /// A job (re)launched: dynamic (LLM) jobs get a *fresh* monitor when
    /// prediction is enabled — each launch restarts the Algorithm-1
    /// series, exactly as the pre-redesign simulator did.
    pub fn on_launch(&mut self, id: BeliefId, spec: &JobSpec) {
        let b = &mut self.beliefs[id];
        b.monitor = match (&spec.compute, self.cfg.prediction, spec.kind) {
            (ComputeModel::Iterative(it), true, JobKind::Llm) => {
                Some(JobMonitor::new(it.trace.n_iters, self.conv))
            }
            _ => None,
        };
    }

    /// One allocator observation from the simulator (`mem_gb` is the
    /// iteration's physical footprint). Returns the converged peak
    /// projection, if the monitor has one.
    pub fn observe(&mut self, id: BeliefId, obs: Observation, mem_gb: f64) -> Option<f64> {
        let b = &mut self.beliefs[id];
        b.observed_peak_gb = b.observed_peak_gb.max(mem_gb);
        let mon = b.monitor.as_mut()?;
        match mon.push(obs) {
            PredictionOutcome::Converged { peak_physical_gb } => {
                b.predicted_peak_gb = Some(peak_physical_gb);
                Some(peak_physical_gb)
            }
            PredictionOutcome::Pending => None,
        }
    }

    /// OOM on an instance of `cur_profile`: the paper reschedules on
    /// the next-largest slice, so the demand becomes that slice's
    /// memory (the whole GPU off the top of the ladder). `observed_gb`
    /// is the footprint that triggered the OOM — hard evidence the
    /// upper bound must never drop below (the demand *point* stays the
    /// ladder walk, so scheduling decisions are unchanged).
    pub fn refine_after_oom(
        &mut self,
        id: BeliefId,
        spec: &GpuSpec,
        cur_profile: usize,
        observed_gb: f64,
    ) {
        let point = match spec.next_larger_profile(cur_profile) {
            Some(next) => spec.profiles[next].mem_gb,
            None => spec.total_mem_gb,
        };
        let b = &mut self.beliefs[id];
        b.observed_peak_gb = b.observed_peak_gb.max(observed_gb);
        let lo = b.observed_peak_gb.min(point);
        b.refine_band(lo, point, point);
        b.monitor = None;
    }

    /// A converged projection exceeded the slice: the demand becomes
    /// the projected peak widened by the safety margin; the band keeps
    /// the fit's z-upper requested bound as its top edge.
    pub fn refine_from_prediction(&mut self, id: BeliefId, peak_gb: f64) {
        let margin = self.cfg.knobs.safety_margin;
        let b = &mut self.beliefs[id];
        let point = peak_gb * (1.0 + margin);
        let hi = b
            .monitor
            .as_ref()
            .and_then(|m| m.latest_fit())
            .map(|f| f.mem_pred_gb)
            .unwrap_or(point)
            .max(point);
        let lo = b.observed_peak_gb.min(point);
        b.predicted_peak_gb = Some(peak_gb);
        b.refine_band(lo, point, hi);
        b.monitor = None;
    }

    /// External (wall-clock) observation — the server's per-replica KV
    /// usage sample. Tracked in the belief's own series so an external
    /// fit engine (the AOT PJRT predictor) can be run over it.
    pub fn observe_external(&mut self, id: BeliefId, obs: Observation, mem_gb: f64) {
        let b = &mut self.beliefs[id];
        b.observed_peak_gb = b.observed_peak_gb.max(mem_gb);
        let (m, r) = b.external.get_or_insert_with(|| (Vec::new(), Vec::new()));
        m.push(obs.req_mem_gb);
        r.push(1.0 / obs.reuse_ratio.max(1e-6));
    }

    /// Fold an externally-computed fit (e.g. the PJRT Pallas engine)
    /// into the belief: the projection becomes the demand point, the
    /// fit's z-upper requested bound the band top. Returns the refined
    /// demand so callers can compare it against their budget.
    pub fn apply_external_fit(&mut self, id: BeliefId, stats: &FitStats) -> f64 {
        let b = &mut self.beliefs[id];
        let point = stats.peak_physical_gb;
        b.predicted_peak_gb = Some(point);
        b.refine_band(
            b.observed_peak_gb.min(point),
            point,
            stats.mem_pred_gb.max(point),
        );
        b.demand_gb()
    }

    /// Predicted-vs-actual accuracy over every belief with a converged
    /// prediction (actual = realized peak recorded at registration).
    pub fn accuracy(&self) -> PredictionAccuracy {
        let mut acc = PredictionAccuracy::default();
        let mut err_sum = 0.0;
        for b in &self.beliefs {
            if b.observed_peak_gb > 0.0 {
                acc.n_tracked += 1;
            }
            if let Some(pred) = b.predicted_peak_gb {
                let actual = if b.true_peak_gb > 0.0 {
                    b.true_peak_gb
                } else {
                    b.observed_peak_gb
                };
                if actual > 0.0 {
                    acc.n_predicted += 1;
                    err_sum += (pred - actual).abs() / actual;
                }
            }
        }
        if acc.n_predicted > 0 {
            acc.mean_abs_pct_err = err_sum / acc.n_predicted as f64;
        }
        acc
    }

    /// Checkpoint the ledger: every belief, in registration order. The
    /// configuration (`BeliefConfig` + convergence policy) is
    /// *structural* — a restoring orchestrator is constructed with the
    /// same config and only the per-job state travels in the snapshot.
    pub fn snapshot(&self) -> BeliefSnapshot {
        BeliefSnapshot(Json::Arr(
            self.beliefs.iter().map(|b| b.to_snap_json()).collect(),
        ))
    }

    /// Overwrite the ledger's beliefs from a snapshot.
    pub fn restore(&mut self, snap: &BeliefSnapshot) -> Result<()> {
        let arr = match &snap.0 {
            Json::Arr(v) => v,
            other => bail!("belief snapshot must be an array, got {other}"),
        };
        self.beliefs = arr
            .iter()
            .map(MemoryBelief::from_snap_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}

/// Serialized [`BeliefLedger`] state (beliefs only; see
/// [`BeliefLedger::snapshot`]).
#[derive(Debug, Clone)]
pub struct BeliefSnapshot(pub Json);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::llm;

    fn ledger(prediction: bool) -> BeliefLedger {
        BeliefLedger::new(BeliefConfig::new(prediction))
    }

    #[test]
    fn knobs_default_matches_paper_and_roundtrips() {
        let d = BeliefKnobs::default();
        assert_eq!(d.z, Z_99);
        assert_eq!(d.window, ConvergenceCfg::default().window);
        assert_eq!(d.safety_margin, 0.0);
        // default knobs select exactly the paper's convergence policy
        let cfg = d.conv_cfg();
        let paper = ConvergenceCfg::default();
        assert_eq!(cfg.min_obs, paper.min_obs);
        assert_eq!(cfg.window, paper.window);
        assert_eq!(cfg.rel_tol, paper.rel_tol);
        assert_eq!(cfg.z, paper.z);

        let k = BeliefKnobs {
            z: 1.96,
            window: 5,
            safety_margin: 0.1,
        };
        assert_eq!(BeliefKnobs::from_json(&k.to_json()).unwrap(), k);
        assert_eq!(
            BeliefKnobs::from_json(&Json::parse("{}").unwrap()).unwrap(),
            BeliefKnobs::default()
        );
        for bad in [
            r#"{"z": -1}"#,
            r#"{"window": 0}"#,
            r#"{"window": 2.5}"#,
            r#"{"safety_margin": -0.5}"#,
        ] {
            assert!(
                BeliefKnobs::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }

    /// Property: the ledger with default knobs converges at exactly the
    /// same iteration, to exactly the same peak, as a bare JobMonitor —
    /// the bit-for-bit bridge the parity suite stands on.
    #[test]
    fn ledger_reproduces_bare_monitor_decisions_bit_for_bit() {
        for (w, seed) in [(llm::qwen2_7b(), 7u64), (llm::flan_t5_train(), 9)] {
            let job = w.job(seed);
            let ComputeModel::Iterative(it) = &job.compute else {
                unreachable!()
            };
            let trace = it.trace.generate(it.trace_seed);
            let mut lg = ledger(true);
            let id = lg.register(job.est, job.true_mem_gb);
            lg.on_launch(id, &job);
            let mut bare = JobMonitor::new(it.trace.n_iters, ConvergenceCfg::default());
            for i in 0..trace.len() {
                let obs = trace.observation(i);
                let via_ledger = lg.observe(id, obs, trace.phys_gb[i]);
                let via_bare = match bare.push(obs) {
                    PredictionOutcome::Converged { peak_physical_gb } => Some(peak_physical_gb),
                    PredictionOutcome::Pending => None,
                };
                match (via_ledger, via_bare) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.to_bits(), b.to_bits(), "{} iter {i}", w.name)
                    }
                    (None, None) => {}
                    (a, b) => panic!("{} iter {i}: ledger {a:?} vs bare {b:?}", w.name),
                }
            }
        }
    }

    /// Property: the upper bound never drops below any observed peak,
    /// across observations and every refinement kind.
    #[test]
    fn upper_bound_never_drops_below_observed_peak() {
        use crate::util::Rng;
        let spec = GpuSpec::a100_40gb();
        for seed in [1u64, 2, 3, 4, 5] {
            let job = llm::llama3_3b().job(seed);
            let ComputeModel::Iterative(it) = &job.compute else {
                unreachable!()
            };
            let trace = it.trace.generate(it.trace_seed);
            let mut lg = ledger(true);
            let id = lg.register(job.est, job.true_mem_gb);
            lg.on_launch(id, &job);
            let mut rng = Rng::new(seed);
            let mut peak_seen = 0.0f64;
            for i in 0..trace.len() {
                let mem = trace.phys_gb[i];
                peak_seen = peak_seen.max(mem);
                let converged = lg.observe(id, trace.observation(i), mem);
                assert!(
                    lg.get(id).upper_bound_gb() + 1e-12 >= peak_seen,
                    "seed {seed} iter {i}"
                );
                // randomly interleave every refinement kind
                match rng.below(7) {
                    0 => {
                        lg.refine_after_oom(id, &spec, 0, mem);
                        lg.on_launch(id, &job); // relaunch on the bigger slice
                    }
                    1 => {
                        if let Some(p) = converged {
                            lg.refine_from_prediction(id, p);
                            lg.on_launch(id, &job); // relaunch
                        }
                    }
                    2 => {
                        let _ = lg.apply_external_fit(
                            id,
                            &crate::predictor::host::fit_one(
                                &trace.req_gb[..=i],
                                &trace.req_gb[..=i].iter().map(|_| 1.0).collect::<Vec<_>>(),
                                trace.len() as f64,
                                Z_99,
                            ),
                        );
                    }
                    _ => {}
                }
                assert!(
                    lg.get(id).upper_bound_gb() + 1e-12 >= peak_seen,
                    "seed {seed} iter {i} post-refine"
                );
            }
            assert!(lg.get(id).observed_peak_gb() > 0.0);
        }
    }

    /// Property: refinement generations are strictly monotone.
    #[test]
    fn generations_are_monotone() {
        let spec = GpuSpec::a100_40gb();
        let job = llm::qwen2_7b().job(3);
        let mut lg = ledger(true);
        let id = lg.register(job.est, job.true_mem_gb);
        assert_eq!(lg.get(id).generation(), 0);
        let mut last = 0;
        lg.refine_after_oom(id, &spec, 0, 6.0);
        assert!(lg.get(id).generation() > last);
        last = lg.get(id).generation();
        lg.refine_from_prediction(id, 12.5);
        assert!(lg.get(id).generation() > last);
        last = lg.get(id).generation();
        // observations alone do not fabricate refinements
        lg.on_launch(id, &job);
        lg.observe(id, Observation { req_mem_gb: 8.0, reuse_ratio: 1.0 }, 8.0);
        assert_eq!(lg.get(id).generation(), last);
        let _ = lg.apply_external_fit(
            id,
            &crate::predictor::host::fit_one(&[8.0, 8.5, 9.0], &[1.0, 1.0, 1.0], 50.0, Z_99),
        );
        assert!(lg.get(id).generation() > last);
    }

    #[test]
    fn oom_refinement_walks_the_gpu_ladder() {
        let spec = GpuSpec::a100_40gb();
        let job = llm::qwen2_7b().job(1);
        let mut lg = ledger(false);
        let id = lg.register(job.est, job.true_mem_gb);
        assert!(lg.get(id).is_unknown());
        lg.refine_after_oom(id, &spec, 0, 6.2);
        assert_eq!(lg.get(id).demand_gb(), 10.0);
        lg.refine_after_oom(id, &spec, 1, 10.4);
        assert_eq!(lg.get(id).demand_gb(), 20.0);
        lg.refine_after_oom(id, &spec, 4, 41.0);
        assert_eq!(lg.get(id).demand_gb(), 40.0);
        assert_eq!(lg.get(id).generation(), 3);
        // the OOMing footprints are observed evidence: the upper bound
        // tracks them even past the demand point (40 GB total ladder).
        assert_eq!(lg.get(id).observed_peak_gb(), 41.0);
        assert!(lg.get(id).upper_bound_gb() >= 41.0);
    }

    #[test]
    fn safety_margin_widens_the_restart_demand() {
        let mut cfg = BeliefConfig::new(true);
        cfg.knobs.safety_margin = 0.1;
        let mut lg = BeliefLedger::new(cfg);
        let id = lg.register(Estimate::unknown_upfront(2), 12.0);
        lg.refine_from_prediction(id, 12.0);
        assert!((lg.get(id).demand_gb() - 13.2).abs() < 1e-12);
        // default margin leaves the projection untouched (parity)
        let mut lg0 = ledger(true);
        let id0 = lg0.register(Estimate::unknown_upfront(2), 12.0);
        lg0.refine_from_prediction(id0, 12.0);
        assert_eq!(lg0.get(id0).demand_gb(), 12.0);
    }

    #[test]
    fn prediction_disabled_means_no_monitor() {
        let job = llm::qwen2_7b().job(2);
        let mut lg = ledger(false);
        let id = lg.register(job.est, job.true_mem_gb);
        lg.on_launch(id, &job);
        assert!(lg.get(id).monitor().is_none());
        let got = lg.observe(id, Observation { req_mem_gb: 9.0, reuse_ratio: 1.0 }, 9.0);
        assert!(got.is_none());
        assert_eq!(lg.get(id).observed_peak_gb(), 9.0);
    }

    #[test]
    fn external_series_feeds_accuracy_and_alerts() {
        let mut lg = ledger(false);
        let id = lg.register(Estimate::unknown_upfront(1), 0.0);
        for i in 0..16 {
            let gb = 1.0 + 0.1 * i as f64;
            lg.observe_external(id, Observation { req_mem_gb: gb, reuse_ratio: 1.0 }, gb);
        }
        let (m, r) = lg.get(id).external_series().unwrap();
        assert_eq!(m.len(), 16);
        assert_eq!(r.len(), 16);
        let fit = crate::predictor::host::fit_one(m, r, 64.0, Z_99);
        let demand = lg.apply_external_fit(id, &fit);
        assert!(demand > 2.0, "projected KV demand {demand}");
        assert_eq!(lg.get(id).demand_gb(), demand);
        let acc = lg.accuracy();
        assert_eq!(acc.n_tracked, 1);
        assert_eq!(acc.n_predicted, 1);
    }

    #[test]
    fn accuracy_measures_prediction_error_against_true_peak() {
        let mut lg = ledger(true);
        let a = lg.register(Estimate::unknown_upfront(1), 10.0);
        let b = lg.register(Estimate::unknown_upfront(1), 20.0);
        lg.observe(a, Observation { req_mem_gb: 5.0, reuse_ratio: 1.0 }, 5.0);
        lg.observe(b, Observation { req_mem_gb: 5.0, reuse_ratio: 1.0 }, 5.0);
        lg.refine_from_prediction(a, 11.0); // 10% err
        lg.refine_from_prediction(b, 19.0); // 5% err
        let acc = lg.accuracy();
        assert_eq!(acc.n_tracked, 2);
        assert_eq!(acc.n_predicted, 2);
        assert!((acc.mean_abs_pct_err - 0.075).abs() < 1e-12, "{}", acc.mean_abs_pct_err);
    }

    #[test]
    fn external_observations_never_escape_the_upper_bound() {
        // Property (KV admission soundness): after any interleaving of
        // observe_external and apply_external_fit, the belief's upper
        // bound covers every observation ever fed — an admission gate
        // checking `upper_bound_gb() <= budget` can never have let a
        // larger reality through.
        let mut rng = crate::util::Rng::new(1234);
        for _ in 0..20 {
            let mut lg = ledger(false);
            let id = lg.register(Estimate::unknown_upfront(1), 0.0);
            let mut peak = 0.0f64;
            for step in 0..60 {
                let gb = rng.range_f64(0.5, 24.0);
                peak = peak.max(gb);
                lg.observe_external(id, Observation { req_mem_gb: gb, reuse_ratio: 1.0 }, gb);
                if step % 7 == 6 {
                    let (m, r) = lg.get(id).external_series().unwrap();
                    let fit = crate::predictor::host::fit_one(m, r, 96.0, Z_99);
                    let demand = lg.apply_external_fit(id, &fit);
                    // the returned demand IS the refined demand
                    assert_eq!(demand, lg.get(id).demand_gb());
                }
                let b = lg.get(id);
                assert_eq!(b.observed_peak_gb(), peak);
                assert!(
                    b.upper_bound_gb() >= peak,
                    "bound {} < observed peak {peak}",
                    b.upper_bound_gb()
                );
            }
            let (m, r) = lg.get(id).external_series().unwrap();
            assert_eq!(m.len(), 60);
            assert_eq!(r.len(), 60);
        }
    }

    #[test]
    fn external_fit_band_clamps_above_observed_peak() {
        // A fit whose projection sits *below* an already-observed peak
        // must not shrink the band under reality: refine_band clamps
        // the top edge to the observed peak.
        let mut lg = ledger(false);
        let id = lg.register(Estimate::unknown_upfront(1), 0.0);
        // one early spike, then a flat low series the fit will track
        lg.observe_external(id, Observation { req_mem_gb: 18.0, reuse_ratio: 1.0 }, 18.0);
        for _ in 0..31 {
            lg.observe_external(id, Observation { req_mem_gb: 2.0, reuse_ratio: 1.0 }, 2.0);
        }
        let (m, r) = lg.get(id).external_series().unwrap();
        let fit = crate::predictor::host::fit_one(m, r, 48.0, Z_99);
        let demand = lg.apply_external_fit(id, &fit);
        let b = lg.get(id);
        assert!(demand < 18.0, "flat series projects low: {demand}");
        assert_eq!(b.observed_peak_gb(), 18.0);
        assert!(b.upper_bound_gb() >= 18.0, "band top {}", b.upper_bound_gb());
        // inverted-reuse bookkeeping: reuse 1.0 stores inv_reuse 1.0
        let (_, inv) = b.external_series().unwrap();
        assert!(inv.iter().all(|&x| (x - 1.0).abs() < 1e-12));
    }

    /// Checkpoint property: a ledger restored from serialized text
    /// re-serializes byte-identically AND continues producing
    /// bit-identical convergence decisions — mid-fit monitor state and
    /// all.
    #[test]
    fn ledger_snapshot_restores_mid_fit_state_bit_for_bit() {
        let job = llm::qwen2_7b().job(3);
        let ComputeModel::Iterative(it) = &job.compute else {
            unreachable!()
        };
        let trace = it.trace.generate(it.trace_seed);
        let mut lg = ledger(true);
        let id = lg.register(job.est, job.true_mem_gb);
        lg.on_launch(id, &job);
        // take the snapshot mid-series, before convergence has latched
        let cut = 4;
        for i in 0..cut {
            lg.observe(id, trace.observation(i), trace.phys_gb[i]);
        }
        let text = lg.snapshot().0.to_string();
        let mut fork = ledger(true);
        fork.restore(&BeliefSnapshot(Json::parse(&text).unwrap()))
            .unwrap();
        assert_eq!(fork.snapshot().0.to_string(), text);
        for i in cut..trace.len() {
            let a = lg.observe(id, trace.observation(i), trace.phys_gb[i]);
            let b = fork.observe(id, trace.observation(i), trace.phys_gb[i]);
            match (a, b) {
                (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits(), "iter {i}"),
                (None, None) => {}
                (x, y) => panic!("iter {i}: {x:?} vs {y:?}"),
            }
        }
        assert_eq!(
            lg.get(id).predicted_peak_gb().map(f64::to_bits),
            fork.get(id).predicted_peak_gb().map(f64::to_bits)
        );
    }
}
