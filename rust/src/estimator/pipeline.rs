//! The [`Estimator`] trait and the default three-tier pipeline: one
//! entry point turning a workload description into a rich [`Estimate`].
//!
//! Each tier answers the inputs it understands and passes on the rest;
//! the pipeline asks its tiers in order. The default pipeline
//! ([`default_pipeline`]) wires the paper's three tiers
//! (compiler analysis → DNNMem → time-series/unknown) and is what every
//! workload constructor goes through, so a custom pipeline (a learned
//! estimator, a profiling cache) swaps in at one seam. Its output
//! reproduces the legacy flat [`MemoryEstimate`] bit for bit
//! ([`Estimate::to_legacy`]); the property tests below pin that for
//! every paper mix.

use std::sync::OnceLock;

use super::compiler_analysis::{analyze, KernelResource};
use super::dnnmem::{self, ModelDef, Optimizer};
use super::{Estimate, EstimationMethod};

/// What a tier is asked to estimate: the per-kind workload description
/// the paper's tiers consume.
#[derive(Debug, Clone, Copy)]
pub enum EstimateInput<'a> {
    /// A compiled scientific kernel (Rodinia): the compiler pass's
    /// resource descriptor plus the GPU's GPC count for warp folding.
    Kernel {
        /// The compiler pass's resource descriptor.
        resource: &'a KernelResource,
        /// The target GPU's GPC count (for warp folding).
        total_gpcs: u8,
    },
    /// A DNN training/inference job: layer graph + batch + optimizer.
    Model {
        /// The layer graph.
        model: &'a ModelDef,
        /// Batch size.
        batch: u64,
        /// Optimizer (drives per-weight state).
        opt: Optimizer,
        /// Compute demand in GPC units.
        demand_gpcs: u8,
    },
    /// A dynamically-growing workload (LLM): nothing is knowable
    /// upfront beyond the compute demand.
    Dynamic {
        /// Compute demand in GPC units.
        demand_gpcs: u8,
    },
}

/// One estimation tier. `estimate` returns `None` for inputs the tier
/// does not understand, letting the pipeline fall through.
pub trait Estimator: Send + Sync {
    /// Stable tier name (reports and provenance).
    fn name(&self) -> &'static str;
    /// The tier's estimate, or `None` if the input kind is not its job.
    fn estimate(&self, input: &EstimateInput) -> Option<Estimate>;
}

/// Tier 1 — CASE-style compile-time analysis (exact band).
pub struct CompilerAnalysisEstimator;

impl Estimator for CompilerAnalysisEstimator {
    fn name(&self) -> &'static str {
        "compiler-analysis"
    }

    fn estimate(&self, input: &EstimateInput) -> Option<Estimate> {
        match input {
            EstimateInput::Kernel {
                resource,
                total_gpcs,
            } => Some(analyze(resource, *total_gpcs).to_estimate()),
            _ => None,
        }
    }
}

/// Tier 2 — DNNMem-style offline model-size estimation. The point is
/// the DNNMem total; the band's lower edge strips the
/// allocator-fragmentation slack (the estimate's dominant uncertainty).
pub struct DnnMemEstimator;

impl Estimator for DnnMemEstimator {
    fn name(&self) -> &'static str {
        "dnnmem"
    }

    fn estimate(&self, input: &EstimateInput) -> Option<Estimate> {
        match input {
            EstimateInput::Model {
                model,
                batch,
                opt,
                demand_gpcs,
            } => {
                let e = dnnmem::estimate(model, *batch, *opt);
                let raw = e.weights_gb
                    + e.gradients_gb
                    + e.optimizer_gb
                    + e.activations_gb
                    + e.workspace_gb;
                Some(Estimate::banded(
                    raw + e.context_gb,
                    e.total_gb,
                    e.total_gb,
                    *demand_gpcs,
                    EstimationMethod::ModelSize,
                ))
            }
            _ => None,
        }
    }
}

/// Tier 3 — the time-series tier's a-priori answer: explicitly unknown.
pub struct TimeSeriesEstimator;

impl Estimator for TimeSeriesEstimator {
    fn name(&self) -> &'static str {
        "time-series"
    }

    fn estimate(&self, input: &EstimateInput) -> Option<Estimate> {
        match input {
            EstimateInput::Dynamic { demand_gpcs } => {
                Some(Estimate::unknown_upfront(*demand_gpcs))
            }
            _ => None,
        }
    }
}

/// An ordered tier list behind one entry point.
pub struct EstimationPipeline {
    tiers: Vec<Box<dyn Estimator>>,
}

impl EstimationPipeline {
    /// A pipeline from an explicit tier order.
    pub fn new(tiers: Vec<Box<dyn Estimator>>) -> EstimationPipeline {
        EstimationPipeline { tiers }
    }

    /// The paper's three tiers in order.
    pub fn paper_default() -> EstimationPipeline {
        EstimationPipeline::new(vec![
            Box::new(CompilerAnalysisEstimator),
            Box::new(DnnMemEstimator),
            Box::new(TimeSeriesEstimator),
        ])
    }

    /// Ask each tier in order; panics if no tier understands the input
    /// (a pipeline misconfiguration, not a runtime condition).
    pub fn estimate(&self, input: &EstimateInput) -> Estimate {
        self.tiers
            .iter()
            .find_map(|t| t.estimate(input))
            .expect("no estimation tier accepts this input")
    }
}

impl Estimator for EstimationPipeline {
    fn name(&self) -> &'static str {
        "pipeline"
    }

    fn estimate(&self, input: &EstimateInput) -> Option<Estimate> {
        self.tiers.iter().find_map(|t| t.estimate(input))
    }
}

/// The shared default pipeline every workload constructor routes
/// through (built once; tiers are stateless).
pub fn default_pipeline() -> &'static EstimationPipeline {
    static PIPELINE: OnceLock<EstimationPipeline> = OnceLock::new();
    PIPELINE.get_or_init(EstimationPipeline::paper_default)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::MemoryEstimate;
    use crate::workloads::{dnn, llm, mix, rodinia};

    #[test]
    fn tiers_dispatch_by_input_kind() {
        let p = default_pipeline();
        let bench = rodinia::by_name("gaussian").unwrap();
        let kr = bench.kernel_resource();
        let e = p.estimate(&EstimateInput::Kernel {
            resource: &kr,
            total_gpcs: 7,
        });
        assert_eq!(e.method, EstimationMethod::CompilerAnalysis);
        assert!(!e.is_unknown());
        assert_eq!(e.lo_gb(), e.hi_gb(), "compiler tier is exact");

        let d = dnn::vgg16_train();
        let e = p.estimate(&EstimateInput::Model {
            model: &d.model,
            batch: d.batch,
            opt: d.opt,
            demand_gpcs: d.demand_gpcs,
        });
        assert_eq!(e.method, EstimationMethod::ModelSize);
        assert!(e.lo_gb() < e.point_gb(), "fragmentation slack widens the band");
        assert_eq!(e.hi_gb(), e.point_gb());

        let e = p.estimate(&EstimateInput::Dynamic { demand_gpcs: 2 });
        assert!(e.is_unknown());
        assert_eq!(e.method, EstimationMethod::TimeSeries);
    }

    /// The property the whole redesign hangs on: for every job of every
    /// paper mix, the pipeline-produced estimate collapses to exactly
    /// the legacy flat `MemoryEstimate` the old constructors baked in.
    #[test]
    fn default_pipeline_reproduces_legacy_estimates_on_all_paper_mixes() {
        use crate::config::DEFAULT_SEED;
        let names: Vec<&str> = mix::RODINIA_MIXES
            .iter()
            .chain(&mix::ML_MIXES)
            .chain(&mix::LLM_MIXES)
            .copied()
            .collect();
        let mut checked = 0usize;
        for name in names {
            let m = mix::by_name(name, DEFAULT_SEED).unwrap();
            for job in &m.jobs {
                // Re-derive the legacy value straight from the tier
                // functions (the pre-pipeline construction path).
                let legacy = match job.est.method {
                    EstimationMethod::CompilerAnalysis => {
                        let bench = rodinia::by_name(&job.name).unwrap();
                        MemoryEstimate {
                            mem_gb: analyze(&bench.kernel_resource(), 7).mem_gb,
                            compute_gpcs: analyze(&bench.kernel_resource(), 7).gpcs_folded,
                            method: EstimationMethod::CompilerAnalysis,
                        }
                    }
                    EstimationMethod::ModelSize => MemoryEstimate {
                        mem_gb: job.true_mem_gb, // DNN jobs: estimate == DNNMem total
                        compute_gpcs: job.est.compute_gpcs,
                        method: EstimationMethod::ModelSize,
                    },
                    EstimationMethod::TimeSeries => MemoryEstimate {
                        mem_gb: 0.0,
                        compute_gpcs: job.est.compute_gpcs,
                        method: EstimationMethod::TimeSeries,
                    },
                };
                assert_eq!(job.est.to_legacy(), legacy, "{name}/{}", job.name);
                assert_eq!(job.est.generation, 0, "a-priori estimates are generation 0");
                checked += 1;
            }
        }
        assert!(checked > 200, "swept {checked} jobs");
        // and the dynamic tier: every LLM template starts unknown
        for w in llm::all() {
            assert!(w.job(DEFAULT_SEED).est.is_unknown());
        }
    }
}
