//! DNNMem-style offline model-size estimation (paper §4.3, ref [7]).
//!
//! Walks a layer-graph definition and sums the components DNNMem
//! accounts for: weights, gradients, optimizer state, activations
//! (forward tape kept for backward, including BN/ReLU intermediates),
//! cuDNN im2col/cuBLAS workspace, CUDA context, and an
//! allocator-fragmentation factor. The resulting estimate seeds the
//! scheduler's slice choice for DNN training jobs; if it is too small
//! the OOM-restart policy grows the slice.

/// One layer of a model graph (spatial dims tracked explicitly).
#[derive(Debug, Clone)]
pub enum Layer {
    /// 2-D convolution producing `[out_ch, out_h, out_w]`.
    Conv2d {
        /// Input channels.
        in_ch: u64,
        /// Output channels.
        out_ch: u64,
        /// Square kernel size.
        k: u64,
        /// Output height.
        out_h: u64,
        /// Output width.
        out_w: u64,
    },
    /// Fully connected.
    Linear {
        /// Input features.
        d_in: u64,
        /// Output features.
        d_out: u64,
    },
    /// Pooling / activation-only (no weights), output `[ch, h, w]`.
    Pool {
        /// Channels.
        ch: u64,
        /// Output height.
        out_h: u64,
        /// Output width.
        out_w: u64,
    },
    /// Token embedding.
    Embedding {
        /// Vocabulary size.
        vocab: u64,
        /// Embedding dimension.
        dim: u64,
    },
    /// Transformer encoder block over `[seq, dim]` (BERT-style).
    TransformerBlock {
        /// Sequence length.
        seq: u64,
        /// Model dimension.
        dim: u64,
        /// Feed-forward hidden dimension.
        ffn: u64,
    },
    /// Normalization over `dim` features.
    Norm {
        /// Feature dimension.
        dim: u64,
    },
}

/// Optimizer state multiplier per weight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    /// No extra state (inference).
    None,
    /// One momentum buffer.
    Sgd,
    /// Two moment buffers.
    Adam,
}

impl Optimizer {
    fn state_per_weight(self) -> f64 {
        match self {
            Optimizer::None => 0.0,
            Optimizer::Sgd => 1.0,
            Optimizer::Adam => 2.0,
        }
    }
}

/// A model definition: named layer list.
#[derive(Debug, Clone)]
pub struct ModelDef {
    /// Model name (reporting only).
    pub name: String,
    /// The layer graph, in forward order.
    pub layers: Vec<Layer>,
}

/// DNNMem-style breakdown (all GB).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DnnEstimate {
    /// Model weights.
    pub weights_gb: f64,
    /// Gradient buffers (one per weight in training).
    pub gradients_gb: f64,
    /// Optimizer state (momentum/moment buffers).
    pub optimizer_gb: f64,
    /// Forward tape kept for backward.
    pub activations_gb: f64,
    /// cuDNN im2col / cuBLAS workspace.
    pub workspace_gb: f64,
    /// CUDA context overhead.
    pub context_gb: f64,
    /// Sum of all components with the fragmentation factor applied.
    pub total_gb: f64,
}

const BYTES: f64 = 4.0; // fp32 training
const CONTEXT_GB: f64 = 0.45;
/// PyTorch caching-allocator slack (reserved-vs-allocated gap).
const FRAGMENTATION: f64 = 1.10;
/// Backward pass holds activation gradients alongside the tape.
const TRAIN_ACT_MULT: f64 = 2.0;
/// Inference keeps only a small working set of activations.
const INFER_ACT_MULT: f64 = 0.15;

impl Layer {
    /// Trainable parameter count.
    fn params(&self) -> u64 {
        match *self {
            Layer::Conv2d {
                in_ch, out_ch, k, ..
            } => in_ch * out_ch * k * k + out_ch,
            Layer::Linear { d_in, d_out } => d_in * d_out + d_out,
            Layer::Pool { .. } => 0,
            Layer::Embedding { vocab, dim } => vocab * dim,
            Layer::TransformerBlock { dim, ffn, .. } => {
                // qkv + out projection + 2 ffn mats + biases + 2 norms
                4 * dim * dim + 2 * dim * ffn + 9 * dim + ffn
            }
            Layer::Norm { dim } => 2 * dim,
        }
    }

    /// Forward-tape elements kept per sample. Conv layers keep the conv
    /// output plus BN/ReLU intermediates (x2.5 in PyTorch's default
    /// eager tape); transformer blocks keep residual streams + scores.
    fn activation_elems(&self) -> f64 {
        match *self {
            Layer::Conv2d {
                out_ch,
                out_h,
                out_w,
                ..
            } => (out_ch * out_h * out_w) as f64 * 2.5,
            Layer::Linear { d_out, .. } => d_out as f64,
            Layer::Pool { ch, out_h, out_w } => (ch * out_h * out_w) as f64,
            Layer::Embedding { dim, .. } => dim as f64,
            Layer::TransformerBlock { seq, dim, ffn } => {
                (seq * dim * 4 + seq * ffn) as f64
            }
            Layer::Norm { dim } => dim as f64,
        }
    }

    /// Batch-scaled im2col scratch (bytes per sample) — peak, reused
    /// across layers, so the estimator takes the max, not the sum.
    fn im2col_bytes_per_sample(&self) -> u64 {
        match *self {
            Layer::Conv2d {
                in_ch,
                k,
                out_h,
                out_w,
                ..
            } => k * k * in_ch * out_h * out_w * 4,
            _ => 0,
        }
    }

    /// Fixed cuBLAS-style workspace (paper §3.2.2: inferred from
    /// CUBLAS_WORKSPACE_CONFIG-style defaults).
    fn fixed_workspace_bytes(&self) -> u64 {
        match *self {
            Layer::Linear { .. } | Layer::TransformerBlock { .. } => 8 << 20,
            _ => 0,
        }
    }
}

/// Estimate peak training/inference memory for `model` at `batch`.
pub fn estimate(model: &ModelDef, batch: u64, opt: Optimizer) -> DnnEstimate {
    let params: u64 = model.layers.iter().map(|l| l.params()).sum();
    let act_elems: f64 = model.layers.iter().map(|l| l.activation_elems()).sum();
    let im2col_peak: u64 = model
        .layers
        .iter()
        .map(|l| l.im2col_bytes_per_sample())
        .max()
        .unwrap_or(0);
    let fixed_ws: u64 = model.layers.iter().map(|l| l.fixed_workspace_bytes()).sum();

    let weights = params as f64 * BYTES / 1e9;
    let training = opt != Optimizer::None;
    let gradients = if training { weights } else { 0.0 };
    let optimizer = weights * opt.state_per_weight();
    let act_factor = if training { TRAIN_ACT_MULT } else { INFER_ACT_MULT };
    let activations = act_elems * batch as f64 * BYTES * act_factor / 1e9;
    let workspace = (im2col_peak * batch + fixed_ws) as f64 / 1e9;
    let raw = weights + gradients + optimizer + activations + workspace;
    DnnEstimate {
        weights_gb: weights,
        gradients_gb: gradients,
        optimizer_gb: optimizer,
        activations_gb: activations,
        workspace_gb: workspace,
        context_gb: CONTEXT_GB,
        total_gb: raw * FRAGMENTATION + CONTEXT_GB,
    }
}

// --------------------------------------------------------------------------
// Model zoo: the four DNN benchmarks of the paper's ML mixes (Table 2).
// Architectures are the standard ones; spatial dims assume 224x224 inputs
// (ImageNet) for the CNNs; BERT sequence length is configurable.
// --------------------------------------------------------------------------

fn conv(in_ch: u64, out_ch: u64, k: u64, hw: u64) -> Layer {
    Layer::Conv2d {
        in_ch,
        out_ch,
        k,
        out_h: hw,
        out_w: hw,
    }
}

/// VGG-16 (conv stacks + 3 FC layers), ~138M params.
pub fn vgg16() -> ModelDef {
    let mut layers = vec![
        conv(3, 64, 3, 224),
        conv(64, 64, 3, 224),
        Layer::Pool { ch: 64, out_h: 112, out_w: 112 },
        conv(64, 128, 3, 112),
        conv(128, 128, 3, 112),
        Layer::Pool { ch: 128, out_h: 56, out_w: 56 },
        conv(128, 256, 3, 56),
        conv(256, 256, 3, 56),
        conv(256, 256, 3, 56),
        Layer::Pool { ch: 256, out_h: 28, out_w: 28 },
        conv(256, 512, 3, 28),
        conv(512, 512, 3, 28),
        conv(512, 512, 3, 28),
        Layer::Pool { ch: 512, out_h: 14, out_w: 14 },
        conv(512, 512, 3, 14),
        conv(512, 512, 3, 14),
        conv(512, 512, 3, 14),
        Layer::Pool { ch: 512, out_h: 7, out_w: 7 },
    ];
    layers.push(Layer::Linear { d_in: 512 * 7 * 7, d_out: 4096 });
    layers.push(Layer::Linear { d_in: 4096, d_out: 4096 });
    layers.push(Layer::Linear { d_in: 4096, d_out: 1000 });
    ModelDef { name: "vgg16".into(), layers }
}

/// ResNet-50 approximated as its bottleneck conv stack, ~25M params.
pub fn resnet50() -> ModelDef {
    let mut layers = vec![conv(3, 64, 7, 112), Layer::Pool { ch: 64, out_h: 56, out_w: 56 }];
    let stages: [(u64, u64, u64, u64); 4] =
        [(64, 256, 3, 56), (256, 512, 4, 28), (512, 1024, 6, 14), (1024, 2048, 3, 7)];
    for (cin, cout, blocks, hw) in stages {
        for b in 0..blocks {
            let mid = cout / 4;
            let first_in = if b == 0 { cin } else { cout };
            layers.push(conv(first_in, mid, 1, hw));
            layers.push(conv(mid, mid, 3, hw));
            layers.push(conv(mid, cout, 1, hw));
        }
    }
    layers.push(Layer::Linear { d_in: 2048, d_out: 1000 });
    ModelDef { name: "resnet50".into(), layers }
}

/// Inception-V3 folded to equivalent per-stage convolutions, ~24M params.
pub fn inceptionv3() -> ModelDef {
    let mut layers = vec![
        conv(3, 32, 3, 149),
        conv(32, 32, 3, 147),
        conv(32, 64, 3, 147),
        Layer::Pool { ch: 64, out_h: 73, out_w: 73 },
        conv(64, 80, 1, 73),
        conv(80, 192, 3, 71),
        Layer::Pool { ch: 192, out_h: 35, out_w: 35 },
    ];
    for _ in 0..3 {
        layers.push(conv(288, 288, 3, 35)); // inception-A stage
    }
    for _ in 0..4 {
        layers.push(conv(768, 768, 2, 17)); // inception-B (factorized 7x1)
    }
    for _ in 0..2 {
        layers.push(conv(2048, 2048, 1, 8)); // inception-C (1x1-dominated)
    }
    layers.push(Layer::Linear { d_in: 2048, d_out: 1000 });
    ModelDef { name: "inceptionv3".into(), layers }
}

/// BERT-base with configurable sequence length, ~110M params.
pub fn bert_base(seq: u64) -> ModelDef {
    let mut layers = vec![
        Layer::Embedding { vocab: 30522, dim: 768 },
        Layer::Norm { dim: 768 },
    ];
    for _ in 0..12 {
        layers.push(Layer::TransformerBlock { seq, dim: 768, ffn: 3072 });
    }
    layers.push(Layer::Linear { d_in: 768, d_out: 2 });
    ModelDef { name: format!("bert-base-s{seq}"), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_param_count_is_canonical() {
        // VGG-16 has ~138M parameters.
        let p: u64 = vgg16().layers.iter().map(|l| l.params()).sum();
        assert!((130_000_000..146_000_000).contains(&p), "{p}");
    }

    #[test]
    fn resnet50_param_count_is_canonical() {
        // ~25.5M params; the conv-only approximation lands near that.
        let p: u64 = resnet50().layers.iter().map(|l| l.params()).sum();
        assert!((20_000_000..30_000_000).contains(&p), "{p}");
    }

    #[test]
    fn inceptionv3_param_count_is_canonical() {
        // ~24M params.
        let p: u64 = inceptionv3().layers.iter().map(|l| l.params()).sum();
        assert!((18_000_000..32_000_000).contains(&p), "{p}");
    }

    #[test]
    fn bert_base_param_count_is_canonical() {
        // ~110M params.
        let p: u64 = bert_base(128).layers.iter().map(|l| l.params()).sum();
        assert!((95_000_000..125_000_000).contains(&p), "{p}");
    }

    #[test]
    fn training_cnns_land_in_20gb_class() {
        // Paper §5.2.1: VGG16 / ResNet50 / InceptionV3 training occupy
        // the 20GB MIG slice (i.e. > 10GB, <= 20GB) at these batches.
        for (m, batch) in [(vgg16(), 32), (resnet50(), 64), (inceptionv3(), 64)] {
            let e = estimate(&m, batch, Optimizer::Adam);
            assert!(
                e.total_gb > 10.0 && e.total_gb <= 20.0,
                "{}: {:.1} GB",
                m.name,
                e.total_gb
            );
        }
    }

    #[test]
    fn bert_variants_land_in_5gb_class() {
        // Paper Ml2: BERT variants at ~3.5 GB and ~4.7 GB on 5GB slices.
        let small = estimate(&bert_base(128), 16, Optimizer::Sgd);
        assert!(
            (2.8..4.2).contains(&small.total_gb),
            "{:.2} GB",
            small.total_gb
        );
        let bigger = estimate(&bert_base(256), 16, Optimizer::Sgd);
        assert!(
            (4.0..5.0).contains(&bigger.total_gb) && bigger.total_gb > small.total_gb,
            "{:.2} GB",
            bigger.total_gb
        );
    }

    #[test]
    fn inference_is_much_smaller_than_training() {
        let m = resnet50();
        let t = estimate(&m, 32, Optimizer::Adam);
        let i = estimate(&m, 32, Optimizer::None);
        assert!(i.total_gb < t.total_gb * 0.6, "{} vs {}", i.total_gb, t.total_gb);
        assert_eq!(i.gradients_gb, 0.0);
        assert_eq!(i.optimizer_gb, 0.0);
    }

    #[test]
    fn breakdown_sums_to_total_with_fragmentation() {
        let e = estimate(&vgg16(), 16, Optimizer::Adam);
        let raw =
            e.weights_gb + e.gradients_gb + e.optimizer_gb + e.activations_gb + e.workspace_gb;
        assert!((e.total_gb - (raw * FRAGMENTATION + e.context_gb)).abs() < 1e-9);
    }

    #[test]
    fn activations_scale_linearly_with_batch() {
        let m = vgg16();
        let a = estimate(&m, 8, Optimizer::Sgd).activations_gb;
        let b = estimate(&m, 16, Optimizer::Sgd).activations_gb;
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
