//! End-to-end serving tests over the real PJRT artifacts. Skipped (with
//! a notice) when `make artifacts` has not been run.

use std::sync::Arc;

use migm::runtime::Manifest;
use migm::server::{GenRequest, ServingConfig, ServingSystem};

fn have_artifacts() -> bool {
    let ok = Manifest::default_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("skipping serving e2e: run `make artifacts`");
    }
    ok
}

#[test]
fn batch_of_requests_is_served_with_correct_lengths() {
    if !have_artifacts() {
        return;
    }
    let sys = Arc::new(
        ServingSystem::start(ServingConfig {
            replicas: 2,
            ..Default::default()
        })
        .unwrap(),
    );
    let mut handles = Vec::new();
    for i in 0..10usize {
        let sys = sys.clone();
        let max_new = 2 + (i % 5);
        handles.push(std::thread::spawn(move || {
            let r = sys
                .generate(GenRequest {
                    prompt: vec![(i as i32) + 1, 7, 13],
                    max_new,
                })
                .unwrap();
            (max_new, r)
        }));
    }
    let mut total = 0;
    for h in handles {
        let (max_new, r) = h.join().unwrap();
        assert_eq!(r.tokens.len(), max_new);
        assert!(r.latency_ms > 0.0);
        total += r.tokens.len();
    }
    let st = sys.stats().unwrap();
    assert_eq!(st.requests, 10);
    assert!(st.tokens_generated >= total as u64);
    assert!(st.decode_steps > 0);
}

#[test]
fn same_seed_same_generation() {
    if !have_artifacts() {
        return;
    }
    let gen = |seed| {
        let sys = ServingSystem::start(ServingConfig {
            replicas: 1,
            seed,
            ..Default::default()
        })
        .unwrap();
        let r = sys
            .generate(GenRequest {
                prompt: vec![42, 17],
                max_new: 6,
            })
            .unwrap();
        sys.shutdown();
        r.tokens
    };
    assert_eq!(gen(9), gen(9));
}

#[test]
fn replica_slices_come_from_the_partition_manager() {
    if !have_artifacts() {
        return;
    }
    let sys = ServingSystem::start(ServingConfig {
        replicas: 3,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(sys.replica_slices.len(), 3);
    // slices must be distinct placements
    let mut s = sys.replica_slices.clone();
    s.dedup();
    assert_eq!(s.len(), 3, "{:?}", sys.replica_slices);
    sys.shutdown();
}
