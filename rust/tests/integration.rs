//! Integration tests: schedulers x simulator x partition manager across
//! every published mix, plus randomized property tests (hand-rolled —
//! the offline build has no proptest) on the core invariants.

use std::sync::Arc;

use migm::config::{ExperimentConfig, Scheme, DEFAULT_SEED};
use migm::mig::{
    enumerate_states, GpuSpec, PartitionManager, PartitionPlan, PlanError, ReachabilityTable,
};
use migm::scheduler::{self, run_mix};
use migm::util::{Json, Rng};
use migm::workloads::mix;

fn a100() -> Arc<GpuSpec> {
    Arc::new(GpuSpec::a100_40gb())
}

// ---------------------------------------------------------------- end2end

#[test]
fn every_published_mix_completes_under_every_scheme() {
    let spec = a100();
    let mixes: Vec<&str> = mix::RODINIA_MIXES
        .iter()
        .chain(&mix::ML_MIXES)
        .chain(&mix::LLM_MIXES)
        .copied()
        .collect();
    for name in mixes {
        let m = mix::by_name(name, DEFAULT_SEED).unwrap();
        for scheme in [Scheme::Baseline, Scheme::A, Scheme::B] {
            for pred in [false, true] {
                if scheme == Scheme::Baseline && pred {
                    continue;
                }
                let r = run_mix(spec.clone(), &m, scheme, pred);
                assert_eq!(
                    r.records.len(),
                    m.jobs.len(),
                    "{name} under {scheme:?} pred={pred}: jobs lost or duplicated"
                );
                assert!(r.metrics.makespan_s > 0.0);
                assert!(r.metrics.throughput_jps > 0.0);
                // energy is bounded by the power envelope
                let min_e = spec.idle_power_w * r.metrics.makespan_s;
                let max_e = spec.max_power_w * r.metrics.makespan_s;
                assert!(
                    r.metrics.energy_j >= min_e - 1e-6 && r.metrics.energy_j <= max_e + 1e-6,
                    "{name}: energy {} outside [{min_e}, {max_e}]",
                    r.metrics.energy_j
                );
                assert!(r.metrics.mem_utilization >= 0.0 && r.metrics.mem_utilization <= 1.0);
            }
        }
    }
}

#[test]
fn mig_schemes_beat_baseline_on_every_rodinia_mix() {
    let spec = a100();
    for name in mix::RODINIA_MIXES {
        let m = mix::by_name(name, DEFAULT_SEED).unwrap();
        let base = scheduler::baseline::run(spec.clone(), &m);
        let a = run_mix(spec.clone(), &m, Scheme::A, false);
        assert!(
            a.metrics.throughput_jps > base.metrics.throughput_jps,
            "{name}: A {} !> base {}",
            a.metrics.throughput_jps,
            base.metrics.throughput_jps
        );
        assert!(
            a.metrics.energy_j < base.metrics.energy_j,
            "{name}: A energy {} !< base {}",
            a.metrics.energy_j,
            base.metrics.energy_j
        );
    }
}

#[test]
fn prediction_dominates_no_prediction_for_dynamic_mixes() {
    let spec = a100();
    for name in mix::LLM_MIXES {
        let m = mix::by_name(name, DEFAULT_SEED).unwrap();
        let without = run_mix(spec.clone(), &m, Scheme::A, false);
        let with = run_mix(spec.clone(), &m, Scheme::A, true);
        assert!(
            with.metrics.throughput_jps >= without.metrics.throughput_jps,
            "{name}: pred {} !>= nopred {}",
            with.metrics.throughput_jps,
            without.metrics.throughput_jps
        );
        assert!(with.metrics.oom_restarts <= without.metrics.oom_restarts);
    }
}

#[test]
fn experiment_config_file_roundtrip() {
    let dir = std::env::temp_dir();
    let path = dir.join("migm_test_config.json");
    std::fs::write(
        &path,
        r#"{"gpu": "a100", "mix": "hm2", "scheme": "b", "prediction": false, "seed": 3}"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(&path).unwrap();
    let r = scheduler::run_experiment(&cfg);
    assert_eq!(r.records.len(), 50);
    std::fs::remove_file(&path).ok();
}

#[test]
fn online_poisson_scenario_runs_end_to_end() {
    // The acceptance scenario: a Poisson arrival stream through the
    // orchestrator under every policy, with latency percentiles out.
    let spec = a100();
    let m = mix::ht2(DEFAULT_SEED).with_poisson_arrivals(0.2, DEFAULT_SEED);
    for scheme in [Scheme::Baseline, Scheme::A, Scheme::B] {
        let r = run_mix(spec.clone(), &m, scheme, false);
        assert_eq!(r.records.len(), m.jobs.len(), "{scheme:?}");
        // every job respects its arrival time
        for (i, rec) in r.records.iter().enumerate() {
            assert!(rec.submit_time >= 0.0, "{scheme:?} record {i}");
            assert!(rec.start_time >= rec.submit_time - 1e-9, "{scheme:?} record {i}");
            assert!(rec.finish_time >= rec.start_time, "{scheme:?} record {i}");
        }
        // no job can finish before the first arrival
        let first_arrival = m.arrivals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(r.metrics.makespan_s >= first_arrival);
        assert!(r.latency.p99_turnaround_s >= r.latency.p50_turnaround_s, "{scheme:?}");
        assert!(r.latency.p50_queue_s >= 0.0);
    }
}

#[test]
fn online_and_batch_agree_when_arrivals_are_zero() {
    // An all-zeros arrival trace is the batch scenario by definition.
    let spec = a100();
    let m = mix::ht3(DEFAULT_SEED);
    let zeros = m.clone().with_arrival_trace(vec![0.0; m.jobs.len()]);
    for scheme in [Scheme::Baseline, Scheme::A, Scheme::B] {
        let batch = run_mix(spec.clone(), &m, scheme, false);
        let online = run_mix(spec.clone(), &zeros, scheme, false);
        assert_eq!(batch.metrics.makespan_s, online.metrics.makespan_s, "{scheme:?}");
        assert_eq!(batch.metrics.energy_j, online.metrics.energy_j, "{scheme:?}");
        assert_eq!(batch.metrics.reconfig_ops, online.metrics.reconfig_ops, "{scheme:?}");
    }
}

#[test]
fn a30_and_h100_also_schedule() {
    for gpu in ["a30", "h100"] {
        let cfg = ExperimentConfig::new(gpu, "preliminary-a30", Scheme::A, false, 2).unwrap();
        let r = scheduler::run_experiment(&cfg);
        assert_eq!(r.records.len(), 14, "{gpu}");
    }
}

// ----------------------------------------------------------- properties

/// Property: random alloc/free sequences keep the partition state valid
/// (subset of some full configuration) and never overlap.
#[test]
fn prop_partition_state_always_valid() {
    let spec = a100();
    let table = ReachabilityTable::precompute(&spec);
    let mut rng = Rng::new(0xF00D);
    for _case in 0..200 {
        let mut mgr = PartitionManager::new(spec.clone());
        let mut live: Vec<u32> = Vec::new();
        for _step in 0..40 {
            if rng.bool(0.6) || live.is_empty() {
                let profile = rng.below(spec.profiles.len());
                if let Ok(id) = mgr.alloc(profile) {
                    live.push(id);
                }
            } else {
                let idx = rng.below(live.len());
                let id = live.swap_remove(idx);
                mgr.free(id).unwrap();
            }
            let s = mgr.state();
            assert!(table.is_valid(s), "invalid state {}", s.render(&spec));
            assert!(s.compute_used(&spec) <= spec.total_compute);
            assert_eq!(s.len(), live.len());
        }
        for id in live {
            mgr.free(id).unwrap();
        }
        assert!(mgr.state().is_empty());
        assert_eq!(mgr.current_fcr(), 19);
    }
}

/// Property: alloc always picks an argmax-fcr placement.
#[test]
fn prop_alloc_is_argmax_reachability() {
    let spec = a100();
    let mut rng = Rng::new(0xBEEF);
    for _case in 0..100 {
        let mut mgr = PartitionManager::new(spec.clone());
        for _step in 0..10 {
            let profile = rng.below(spec.profiles.len());
            let cands = mgr.placement_candidates(profile);
            if cands.is_empty() {
                continue;
            }
            let best = cands.iter().map(|(_, f)| *f).max().unwrap();
            let before = mgr.state().clone();
            let id = mgr.alloc(profile).unwrap();
            let placed = mgr.placement_of(id).unwrap();
            let achieved = mgr
                .table()
                .fcr(&before.with(placed))
                .expect("allocated state is valid");
            assert_eq!(achieved, best, "alloc not argmax for profile {profile}");
        }
    }
}

/// Property: any fusion/fission plan the manager produces actually
/// yields an instance of the requested profile when executed
/// transactionally, is priced by the per-op cost model, and leaves a
/// valid state.
#[test]
fn prop_reconfig_plans_are_sound() {
    let spec = a100();
    let mut rng = Rng::new(0xCAFE);
    for _case in 0..150 {
        let mut mgr = PartitionManager::new(spec.clone());
        let mut live = Vec::new();
        // fill with random small/medium instances
        for _ in 0..rng.range(2, 8) {
            let profile = rng.below(3);
            if let Ok(id) = mgr.alloc(profile) {
                live.push(id);
            }
        }
        let want = rng.below(spec.profiles.len());
        if mgr.can_alloc(want) {
            continue;
        }
        if let Ok(plan) = mgr.plan_reconfig(want, &live) {
            assert_eq!(plan.n_creates(), 1);
            assert_eq!(plan.len(), plan.n_destroys() + 1);
            // default (uniform) cost model: every op costs reconfig_op_s
            let cost = mgr.plan_cost_s(&plan).unwrap();
            assert!((cost - plan.len() as f64 * spec.reconfig_op_s).abs() < 1e-12);
            let created = mgr.apply_plan(&plan).unwrap();
            assert_eq!(
                mgr.profile_of(created[0]),
                Some(want),
                "plan did not enable profile {want}"
            );
            assert!(mgr.table().is_valid(mgr.state()));
        }
    }
}

/// Property (the new planner's FSM contract): from **every** enumerated
/// valid partition state, planning with all instances destroyable
/// always succeeds, executing the plan transactionally lands in
/// another valid state (checked via `ReachabilityTable::is_valid`),
/// and — whenever destroys are actually needed — the graph planner
/// picks exactly the destroy subset the legacy exhaustive oracle picks.
#[test]
fn prop_planned_reconfigs_preserve_validity_from_every_state() {
    let spec = a100();
    let (all, _) = enumerate_states(&spec);
    let table = ReachabilityTable::precompute(&spec);
    for s in &all {
        let (mgr, ids) = PartitionManager::from_state(spec.clone(), s);
        for want in 0..spec.profiles.len() {
            // Destroying everything empties the GPU, where any profile
            // fits, so planning can never fail here.
            let plan = mgr
                .plan_reconfig(want, &ids)
                .unwrap_or_else(|e| panic!("{}: profile {want}: {e}", s.render(&spec)));
            let mut m2 = mgr.clone();
            let created = m2.apply_plan(&plan).expect("validated plan applies");
            assert!(
                table.is_valid(m2.state()),
                "invalid state after plan from {}",
                s.render(&spec)
            );
            assert_eq!(m2.profile_of(*created.last().unwrap()), Some(want));
            if plan.n_destroys() > 0 {
                let oracle = mgr
                    .plan_reconfig_exhaustive(want, &ids)
                    .expect("oracle must also find a plan");
                assert_eq!(
                    plan.destroys().collect::<Vec<_>>(),
                    oracle.destroys().collect::<Vec<_>>(),
                    "{}: profile {want}: planner/oracle divergence",
                    s.render(&spec)
                );
            }
        }
    }
}

/// Property: plan execution is all-or-nothing under failure injection —
/// corrupted plans (unknown destroy id, create pinned onto an occupied
/// slot) are rejected atomically, leaving the manager untouched.
#[test]
fn prop_plan_execution_is_all_or_nothing_under_failure_injection() {
    let spec = a100();
    let (all, _) = enumerate_states(&spec);
    for s in all.iter().filter(|s| !s.is_empty()).step_by(7) {
        let (mut mgr, ids) = PartitionManager::from_state(spec.clone(), s);
        let before = mgr.state().clone();
        // unknown destroy id buried in an otherwise-fine plan
        let mut bad = PartitionPlan::destroy_only(ids.iter().copied().chain([9999]));
        bad.push_create(0);
        assert_eq!(mgr.begin(&bad), Err(PlanError::UnknownInstance(9999)));
        assert_eq!(mgr.state(), &before, "begin must not half-apply");
        assert_eq!(mgr.instance_count(), ids.len());
        // create pinned onto an occupied slot
        let occupied = s.placements()[0];
        let mut clash = PartitionPlan::new();
        clash.push_create_at(occupied.profile as usize, occupied.start);
        assert!(matches!(
            mgr.begin(&clash),
            Err(PlanError::Unplaceable { .. })
        ));
        assert_eq!(mgr.state(), &before);
    }
}

/// Property: scheduling is deterministic — same seed, same metrics.
#[test]
fn prop_runs_are_deterministic() {
    let spec = a100();
    for seed in [1u64, 9, 77] {
        let m1 = mix::ht2(seed);
        let m2 = mix::ht2(seed);
        let a = run_mix(spec.clone(), &m1, Scheme::A, false);
        let b = run_mix(spec.clone(), &m2, Scheme::A, false);
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.metrics.energy_j, b.metrics.energy_j);
        assert_eq!(a.metrics.reconfig_ops, b.metrics.reconfig_ops);
    }
}

/// Property: random job batches never lose jobs, and the DES keeps all
/// aggregate invariants, across random sizes/seeds and both schemes.
#[test]
fn prop_random_batches_conserve_jobs() {
    use migm::workloads::rodinia;
    let spec = a100();
    let pool = rodinia::pool();
    let mut rng = Rng::new(0xDADA);
    for case in 0..25 {
        let n = rng.range(3, 25);
        let jobs: Vec<_> = (0..n).map(|_| rng.choice(&pool).job(7)).collect();
        let m = mix::Mix::batch("random", jobs);
        let scheme = if case % 2 == 0 { Scheme::A } else { Scheme::B };
        let r = run_mix(spec.clone(), &m, scheme, false);
        assert_eq!(r.records.len(), n, "case {case}");
        // turnarounds are sane
        for rec in &r.records {
            assert!(rec.finish_time >= rec.submit_time);
            assert!(rec.finish_time <= r.metrics.makespan_s + 1e-9);
        }
    }
}

/// Property: the JSON codec roundtrips arbitrary machine-generated docs.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.choice(&['a', '"', '\\', 'é', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(0x1209);
    for _ in 0..300 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, doc, "{text}");
    }
}
