"""L2: JAX compute graphs that lower into MIGM's AOT artifacts.

Two graphs, both calling the L1 Pallas kernels:

  * ``decode_step`` — one batched decode step of a tiny pre-norm
    transformer LM (the real-compute LLM workload served by the rust
    coordinator in ``examples/llm_serving.rs``). KV caches are carried
    functionally: the step takes them as inputs and returns the updated
    caches, so the rust side owns all state between steps.

  * ``init_hidden`` is folded into decode_step via the embedding table —
    the step takes raw token ids, not hidden states.

Shapes are all static (AOT); variants are points in ``DECODE_VARIANTS``.
Python is build-time only — these functions run once under jax.jit.lower.
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention
from .kernels.matmul import matmul

NEG_INF = -1e9


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """Static hyperparameters of one compiled decode-step variant."""

    name: str
    batch: int = 8  # R: requests batched per step by the rust batcher
    layers: int = 2  # L
    heads: int = 4  # H
    head_dim: int = 64  # Dh
    d_model: int = 256  # D == H * Dh
    d_ff: int = 1024  # F
    max_seq: int = 128  # S: KV-cache capacity
    vocab: int = 512  # V

    def __post_init__(self):
        assert self.d_model == self.heads * self.head_dim

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Flattened (name, shape) list defining the artifact's param order.

        The rust runtime materializes literals in exactly this order; the
        list is exported verbatim into artifacts/manifest.json.
        """
        d, f, v = self.d_model, self.d_ff, self.vocab
        specs = [("embedding", (v, d))]
        for l in range(self.layers):
            specs += [
                (f"layer{l}.ln1", (d,)),
                (f"layer{l}.wqkv", (d, 3 * d)),
                (f"layer{l}.wo", (d, d)),
                (f"layer{l}.ln2", (d,)),
                (f"layer{l}.w1", (d, f)),
                (f"layer{l}.w2", (f, d)),
            ]
        specs.append(("ln_f", (d,)))
        return specs

    def kv_shape(self) -> Tuple[int, ...]:
        return (self.layers, self.batch, self.heads, self.max_seq, self.head_dim)

    def kv_cache_bytes(self) -> int:
        import math

        return 2 * math.prod(self.kv_shape()) * 4

    def param_bytes(self) -> int:
        import math

        return sum(4 * math.prod(s) for _, s in self.param_specs())


DECODE_VARIANTS = [
    DecodeConfig(name="decode_s128"),
    DecodeConfig(name="decode_s256", batch=4, max_seq=256),
]


def rmsnorm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _write_kv(cache, new, pos):
    """cache [R,H,S,Dh], new [R,H,Dh], pos [R] -> cache with row written."""

    def one(c, n, p):
        return jax.lax.dynamic_update_slice(c, n[:, None, :], (0, p, 0))

    return jax.vmap(one)(cache, new, pos)


def decode_step(cfg: DecodeConfig, params, tokens, pos, k_cache, v_cache):
    """One decode step.

    params:   list of arrays per cfg.param_specs()
    tokens:   [R] int32 current token ids
    pos:      [R] int32 write position of the current token (0-based)
    k_cache, v_cache: [L, R, H, S, Dh]
    Returns (next_tokens [R] i32, logits [R, V] f32, k_cache, v_cache).
    """
    r, h, dh, s = cfg.batch, cfg.heads, cfg.head_dim, cfg.max_seq
    it = iter(params)
    emb = next(it)  # [V, D]
    x = emb[tokens]  # [R, D]

    # Additive attention bias: positions <= pos are visible.
    seq = jnp.arange(s, dtype=jnp.int32)
    bias = jnp.where(seq[None, :] <= pos[:, None], 0.0, NEG_INF).astype(jnp.float32)

    new_k, new_v = [], []
    for l in range(cfg.layers):
        ln1, wqkv, wo, ln2, w1, w2 = (next(it) for _ in range(6))
        xn = rmsnorm(x, ln1)
        qkv = matmul(xn, wqkv)  # [R, 3D] — L1 Pallas matmul
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(r, h, dh)
        k = k.reshape(r, h, dh)
        v = v.reshape(r, h, dh)
        kc = _write_kv(k_cache[l], k, pos)
        vc = _write_kv(v_cache[l], v, pos)
        new_k.append(kc)
        new_v.append(vc)
        ctx = decode_attention(q, kc, vc, bias)  # L1 Pallas attention
        x = x + matmul(ctx.reshape(r, h * dh), wo)
        xn = rmsnorm(x, ln2)
        x = x + matmul(jax.nn.gelu(matmul(xn, w1)), w2)

    ln_f = next(it)
    x = rmsnorm(x, ln_f)
    logits = matmul(x, emb.T)  # weight-tied LM head
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tokens, logits, jnp.stack(new_k), jnp.stack(new_v)


def decode_step_flat(cfg: DecodeConfig):
    """Flat-signature wrapper for AOT lowering: fn(*params, tokens, pos, k, v)."""
    n_params = len(cfg.param_specs())

    def fn(*args):
        params = list(args[:n_params])
        tokens, pos, k_cache, v_cache = args[n_params:]
        return decode_step(cfg, params, tokens, pos, k_cache, v_cache)

    return fn


def example_args(cfg: DecodeConfig):
    """ShapeDtypeStructs matching decode_step_flat's signature."""
    f32 = jnp.float32
    i32 = jnp.int32
    args = [jax.ShapeDtypeStruct(s, f32) for _, s in cfg.param_specs()]
    args.append(jax.ShapeDtypeStruct((cfg.batch,), i32))  # tokens
    args.append(jax.ShapeDtypeStruct((cfg.batch,), i32))  # pos
    args.append(jax.ShapeDtypeStruct(cfg.kv_shape(), f32))  # k_cache
    args.append(jax.ShapeDtypeStruct(cfg.kv_shape(), f32))  # v_cache
    return args
