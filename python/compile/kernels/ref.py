"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
is validated against the function of the same name here (pytest +
hypothesis sweeps in python/tests/). Keep these boring and obviously
correct — no tiling, no tricks.
"""

import jax
import jax.numpy as jnp

# 99% two-sided confidence level z-score used by the paper's Alg. 1.
Z_99 = 2.576


def masked_linfit_ref(y, mask):
    """Least-squares fit y ~ a*t + b over masked prefix, plus residual sigma.

    y, mask: [..., W]; t is the iteration index 0..W-1.
    Returns (a, b, sigma), each [...].
    """
    w = y.shape[-1]
    t = jnp.arange(w, dtype=jnp.float32)
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    st = jnp.sum(t * m, axis=-1)
    stt = jnp.sum(t * t * m, axis=-1)
    sy = jnp.sum(y * m, axis=-1)
    sty = jnp.sum(t * y * m, axis=-1)
    denom = n * stt - st * st
    safe = jnp.abs(denom) > 1e-6
    a = jnp.where(safe, (n * sty - st * sy) / jnp.where(safe, denom, 1.0), 0.0)
    b = (sy - a * st) / n
    resid = (y - (a[..., None] * t + b[..., None])) * m
    dof = jnp.maximum(n - 2.0, 1.0)
    sigma = jnp.sqrt(jnp.sum(resid * resid, axis=-1) / dof)
    return a, b, sigma


def linreg_stats_ref(req_mem, inv_reuse, n_valid, horizon, z=Z_99):
    """Reference for the batched peak-memory predictor (paper Alg. 1).

    req_mem, inv_reuse: [B, W] per-iteration series (padded past n_valid).
    n_valid, horizon:   [B] float32.
    Returns stats [B, 8]:
      [a_m, b_m, sigma_m, a_r, b_r, sigma_r, mem_pred, peak_physical]
    where mem_pred is the z-CI upper bound on requested memory at `horizon`
    and peak_physical divides by the z-CI *lower* bound on the inverse
    reuse ratio (less reuse => more physical memory; conservative).
    """
    w = req_mem.shape[-1]
    t = jnp.arange(w, dtype=jnp.float32)
    mask = t[None, :] < n_valid[:, None]
    am, bm, sm = masked_linfit_ref(req_mem, mask)
    ar, br, sr = masked_linfit_ref(inv_reuse, mask)
    mem_pred = am * horizon + bm + z * sm
    inv_lo = jnp.maximum(ar * horizon + br - z * sr, 1.0)
    peak = mem_pred / inv_lo
    return jnp.stack([am, bm, sm, ar, br, sr, mem_pred, peak], axis=-1)


def decode_attention_ref(q, k, v, bias):
    """Single-token decode attention over a KV cache.

    q:    [R, H, Dh]     query for the current token
    k, v: [R, H, S, Dh]  cache (current token already written)
    bias: [R, S]         additive mask (0 for valid positions, -1e9 past len)
    Returns [R, H, Dh].
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("rhsd,rhd->rhs", k, q) * scale + bias[:, None, :]
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("rhs,rhsd->rhd", p, v)


def matmul_ref(x, w):
    """Plain f32 matmul, [M, K] @ [K, N] -> [M, N]."""
    return jnp.matmul(x, w)
