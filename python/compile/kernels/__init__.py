"""L1: Pallas kernels for MIGM's compute hot spots (+ pure-jnp oracles)."""

from . import ref  # noqa: F401
from .attention import decode_attention  # noqa: F401
from .linreg import linreg_stats  # noqa: F401
from .matmul import matmul  # noqa: F401
