"""L1 Pallas kernel: batched time-series peak-memory predictor (paper Alg. 1).

One grid step per tracked job. Each step loads that job's full observation
window (W f32 values for requested memory and for the inverse reuse ratio)
into VMEM, computes two masked least-squares fits plus residual sigmas, and
emits the 8-wide stats row consumed by the rust scheduler.

TPU mapping: the whole row (W <= 256 floats) fits trivially in VMEM; the
reductions are VPU work, not MXU work, so the block shape is simply one row
per grid step and the kernel is memory-bound on the HBM->VMEM stream of the
observation matrix. interpret=True everywhere (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import Z_99


def _fit(t, m, v):
    """Masked least squares of v ~ a*t + b; returns (a, b, sigma)."""
    n = jnp.maximum(jnp.sum(m), 1.0)
    st = jnp.sum(t * m)
    stt = jnp.sum(t * t * m)
    sy = jnp.sum(v * m)
    sty = jnp.sum(t * v * m)
    denom = n * stt - st * st
    safe = jnp.abs(denom) > 1e-6
    a = jnp.where(safe, (n * sty - st * sy) / jnp.where(safe, denom, 1.0), 0.0)
    b = (sy - a * st) / n
    resid = (v - (a * t + b)) * m
    dof = jnp.maximum(n - 2.0, 1.0)
    sigma = jnp.sqrt(jnp.sum(resid * resid) / dof)
    return a, b, sigma


def _linreg_kernel(y_ref, r_ref, nv_ref, hz_ref, out_ref, *, z):
    y = y_ref[0, :]  # [W] requested memory series
    r = r_ref[0, :]  # [W] inverse reuse ratio series
    nv = nv_ref[0, 0]
    h = hz_ref[0, 0]
    w = y.shape[-1]
    t = jax.lax.broadcasted_iota(jnp.float32, (w,), 0)
    m = (t < nv).astype(jnp.float32)
    am, bm, sm = _fit(t, m, y)
    ar, br, sr = _fit(t, m, r)
    mem_pred = am * h + bm + z * sm
    inv_lo = jnp.maximum(ar * h + br - z * sr, 1.0)
    peak = mem_pred / inv_lo
    out_ref[0, :] = jnp.stack([am, bm, sm, ar, br, sr, mem_pred, peak])


@functools.partial(jax.jit, static_argnames=("z",))
def linreg_stats(req_mem, inv_reuse, n_valid, horizon, z=Z_99):
    """Batched Alg. 1 fit. Shapes: [B, W], [B, W], [B], [B] -> [B, 8]."""
    b, w = req_mem.shape
    nv = n_valid.astype(jnp.float32).reshape(b, 1)
    hz = horizon.astype(jnp.float32).reshape(b, 1)
    return pl.pallas_call(
        functools.partial(_linreg_kernel, z=z),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 8), jnp.float32),
        interpret=True,
    )(req_mem, inv_reuse, nv, hz)
