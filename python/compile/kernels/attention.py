"""L1 Pallas kernel: single-token decode attention over a KV cache.

Grid is (requests, heads); each step pulls one head's full cache tile
[S, Dh] into VMEM plus the 1-token query, computes masked softmax
attention, and writes the [Dh] context vector.

TPU mapping (vs. the CUDA flash-decoding the paper's LLM workloads use):
instead of a threadblock-per-split over the sequence with shared-memory
reductions, we block over (request, head) and keep the whole per-head
cache tile resident in VMEM (S*Dh*4B = 32 KiB at S=128, Dh=64 — far under
the ~16 MiB VMEM budget), so the softmax is a single VPU pass and the
p@V contraction feeds the MXU. For longer S this kernel would add a
sequence-block grid axis with an online-softmax accumulator; at serving
shapes here a single tile is strictly better (no rescaling traffic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, b_ref, o_ref, *, scale):
    q = q_ref[0]  # [H, Dh]
    k = k_ref[0]  # [H, S, Dh]
    v = v_ref[0]  # [H, S, Dh]
    bias = b_ref[0, :]  # [S]
    s = jnp.einsum("hsd,hd->hs", k, q) * scale + bias[None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.einsum("hs,hsd->hd", p, v)


@jax.jit
def decode_attention(q, k, v, bias):
    """q [R,H,Dh], k/v [R,H,S,Dh], bias [R,S] -> [R,H,Dh].

    Grid is one step per request with the whole per-request cache tile
    [H, S, Dh] resident in VMEM (H*S*Dh*4B*2 = 256 KiB at the serving
    shapes — far under the ~16 MiB budget). The earlier (request, head)
    grid used 4x more grid steps for no VMEM benefit; fewer, fatter
    steps keep the MXU fed and cut the per-step dispatch overhead
    (§Perf: 32 -> 8 grid steps per call).
    """
    r, h, dh = q.shape
    s = k.shape[2]
    scale = 1.0 / (dh**0.5)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, s, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, s, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, h, dh), jnp.float32),
        interpret=True,
    )(q, k, v, bias)
