"""L1 Pallas kernel: tiled matmul for the decode step's projections/MLP.

Grid tiles the output [M, N] as (M/bm, N/bn); each step streams an
[bm, K] x [K, bn] pair through the MXU. K stays un-tiled because the
decode-step contractions here have K <= 1024 (bm*K + K*bn + bm*bn tiles
stay well under VMEM); a K-grid axis with an accumulator would only add
revisits. Block sizes prefer the MXU-native 128 lane width and fall back
to the full extent for small dims (M = batched requests is typically 8).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(extent, k=256):
    """Largest MXU-aligned tile that divides `extent` and keeps the
    [k, bn] weight tile within a ~2 MiB VMEM slice (bn <= 512 at
    k = 1024). Bigger tiles = fewer grid steps (§Perf)."""
    budget = max(128, (2 << 20) // (4 * max(k, 1)))
    for cand in (512, 256, 128):
        if cand <= budget and extent % cand == 0:
            return cand
    return extent


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@jax.jit
def matmul(x, w):
    """[M, K] @ [K, N] -> [M, N], f32."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (k, k2)
    bm = _pick_block(m, k)
    bn = _pick_block(n, k)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)
