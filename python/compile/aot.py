"""AOT lowering: jax graphs -> HLO *text* artifacts + manifest.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run from python/: ``python -m compile.aot --out-dir ../artifacts``.
This is the ONLY python entrypoint in the deployed system; the rust binary
is self-contained once artifacts exist.
"""

import argparse
import dataclasses
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model, predictor


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: model.DecodeConfig) -> str:
    fn = model.decode_step_flat(cfg)
    return to_hlo_text(jax.jit(fn).lower(*model.example_args(cfg)))


def lower_predictor(cfg: predictor.PredictorConfig) -> str:
    fn = predictor.peak_predictor(cfg)
    return to_hlo_text(jax.jit(fn).lower(*predictor.example_args(cfg)))


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"decode": {}, "predictor": {}}

    for cfg in model.DECODE_VARIANTS:
        text = lower_decode(cfg)
        path = os.path.join(args.out_dir, f"{cfg.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["decode"][cfg.name] = {
            "file": f"{cfg.name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "config": dataclasses.asdict(cfg),
            "params": [
                {"name": n, "shape": list(s)} for n, s in cfg.param_specs()
            ],
            "kv_shape": list(cfg.kv_shape()),
            "kv_cache_bytes": cfg.kv_cache_bytes(),
            "param_bytes": cfg.param_bytes(),
        }
        print(f"wrote {path} ({len(text)} chars)")

    for cfg in predictor.PREDICTOR_VARIANTS:
        text = lower_predictor(cfg)
        path = os.path.join(args.out_dir, f"{cfg.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["predictor"][cfg.name] = {
            "file": f"{cfg.name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "config": dataclasses.asdict(cfg),
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
