"""L2: the batched peak-memory predictor graph (paper Alg. 1) for AOT.

The rust scheduler tracks up to B jobs' allocator series and calls the
compiled artifact with padded [B, W] windows. Output is the [B, 8] stats
matrix from kernels.linreg (slopes, intercepts, sigmas, mem_pred, peak).
"""

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.linreg import linreg_stats


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    name: str = "predictor_b16_w64"
    batch: int = 16  # B: jobs tracked concurrently
    window: int = 64  # W: observation window length


PREDICTOR_VARIANTS = [
    PredictorConfig(),
    PredictorConfig(name="predictor_b4_w128", batch=4, window=128),
]


def peak_predictor(cfg: PredictorConfig):
    def fn(req_mem, inv_reuse, n_valid, horizon):
        return (linreg_stats(req_mem, inv_reuse, n_valid, horizon),)

    return fn


def example_args(cfg: PredictorConfig):
    f32 = jnp.float32
    b, w = cfg.batch, cfg.window
    return [
        jax.ShapeDtypeStruct((b, w), f32),
        jax.ShapeDtypeStruct((b, w), f32),
        jax.ShapeDtypeStruct((b,), f32),
        jax.ShapeDtypeStruct((b,), f32),
    ]
