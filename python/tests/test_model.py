"""L2 decode-step semantics: shapes, cache updates, determinism, masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model

CFG = model.DecodeConfig(name="test", batch=2, layers=1, heads=2, head_dim=16,
                         d_model=32, d_ff=64, max_seq=16, vocab=32)


def make_params(cfg, seed=0):
    g = np.random.default_rng(seed)
    params = []
    for name, shape in cfg.param_specs():
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params.append(np.ones(shape, np.float32))
        else:
            params.append((g.normal(size=shape) * 0.05).astype(np.float32))
    return params


def zeros_kv(cfg):
    return (np.zeros(cfg.kv_shape(), np.float32),
            np.zeros(cfg.kv_shape(), np.float32))


def test_shapes_and_dtypes():
    params = make_params(CFG)
    k, v = zeros_kv(CFG)
    tokens = np.array([1, 2], np.int32)
    pos = np.array([0, 0], np.int32)
    nt, logits, k2, v2 = model.decode_step(CFG, params, tokens, pos, k, v)
    assert nt.shape == (CFG.batch,) and nt.dtype == jnp.int32
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert k2.shape == CFG.kv_shape() and v2.shape == CFG.kv_shape()


def test_cache_written_at_pos_only():
    params = make_params(CFG)
    k, v = zeros_kv(CFG)
    tokens = np.array([1, 2], np.int32)
    pos = np.array([3, 5], np.int32)
    _, _, k2, _ = model.decode_step(CFG, params, tokens, pos, k, v)
    k2 = np.asarray(k2)
    # written rows are nonzero, everything else untouched (still zero)
    assert np.abs(k2[0, 0, :, 3, :]).sum() > 0
    assert np.abs(k2[0, 1, :, 5, :]).sum() > 0
    mask = np.ones(CFG.max_seq, bool)
    mask[3] = False
    assert np.abs(k2[0, 0, :, mask, :]).sum() == 0


def test_deterministic():
    params = make_params(CFG)
    k, v = zeros_kv(CFG)
    tokens = np.array([7, 9], np.int32)
    pos = np.array([0, 0], np.int32)
    a = model.decode_step(CFG, params, tokens, pos, k, v)
    b = model.decode_step(CFG, params, tokens, pos, k, v)
    assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=0, atol=0)


def test_future_positions_do_not_leak():
    """Garbage in cache positions > pos must not change the output."""
    params = make_params(CFG)
    k, v = zeros_kv(CFG)
    tokens = np.array([1, 2], np.int32)
    pos = np.array([2, 2], np.int32)
    # run twice: once with clean cache tail, once with garbage tail
    _, logits_a, _, _ = model.decode_step(CFG, params, tokens, pos, k, v)
    k_dirty = k.copy()
    v_dirty = v.copy()
    k_dirty[:, :, :, 10:, :] = 99.0
    v_dirty[:, :, :, 10:, :] = -99.0
    _, logits_b, _, _ = model.decode_step(CFG, params, tokens, pos, k_dirty, v_dirty)
    assert_allclose(np.asarray(logits_a), np.asarray(logits_b), rtol=1e-5, atol=1e-5)


def test_greedy_decode_loop_progresses():
    """Iterating the step must produce a valid token sequence (smoke e2e)."""
    params = make_params(CFG, seed=3)
    k, v = zeros_kv(CFG)
    tokens = np.array([4, 11], np.int32)
    seq = [tokens.copy()]
    for step in range(5):
        pos = np.full(CFG.batch, step, np.int32)
        nt, _, k, v = model.decode_step(CFG, params, tokens, pos, k, v)
        tokens = np.asarray(nt)
        assert ((tokens >= 0) & (tokens < CFG.vocab)).all()
        seq.append(tokens.copy())
    assert len(seq) == 6


def test_param_specs_roundtrip():
    for cfg in model.DECODE_VARIANTS:
        specs = cfg.param_specs()
        names = [n for n, _ in specs]
        assert len(names) == len(set(names))
        assert specs[0][0] == "embedding"
        assert cfg.param_bytes() > 0 and cfg.kv_cache_bytes() > 0
