"""AOT pipeline: lowering produces parseable HLO text + coherent manifest."""

import json
import os

import pytest

from compile import aot, model, predictor


def test_predictor_lowers_to_hlo_text():
    cfg = predictor.PredictorConfig(name="t", batch=2, window=16)
    text = aot.lower_predictor(cfg)
    assert "HloModule" in text
    assert "ENTRY" in text
    # batched input shape must appear
    assert "f32[2,16]" in text


def test_decode_lowers_to_hlo_text():
    cfg = model.DecodeConfig(name="t", batch=2, layers=1, heads=2, head_dim=16,
                             d_model=32, d_ff=64, max_seq=16, vocab=32)
    text = aot.lower_decode(cfg)
    assert "HloModule" in text
    assert "ENTRY" in text
    # output is a tuple (return_tuple=True): next_tokens s32[2]
    assert "s32[2]" in text


def test_main_writes_manifest(tmp_path, monkeypatch):
    # Use tiny variants to keep the test fast.
    tiny_d = model.DecodeConfig(name="tiny_decode", batch=2, layers=1, heads=2,
                                head_dim=16, d_model=32, d_ff=64, max_seq=16,
                                vocab=32)
    tiny_p = predictor.PredictorConfig(name="tiny_pred", batch=2, window=8)
    monkeypatch.setattr(model, "DECODE_VARIANTS", [tiny_d])
    monkeypatch.setattr(predictor, "PREDICTOR_VARIANTS", [tiny_p])
    monkeypatch.setattr("sys.argv", ["aot", "--out-dir", str(tmp_path)])
    aot.main()

    man = json.loads((tmp_path / "manifest.json").read_text())
    assert "tiny_decode" in man["decode"]
    assert "tiny_pred" in man["predictor"]
    entry = man["decode"]["tiny_decode"]
    assert (tmp_path / entry["file"]).exists()
    assert entry["params"][0]["name"] == "embedding"
    assert entry["kv_shape"] == list(tiny_d.kv_shape())
