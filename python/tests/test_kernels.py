"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes and seeds; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.linreg import linreg_stats
from compile.kernels.matmul import matmul

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- linreg


@settings(**SETTINGS)
@given(
    b=st.integers(1, 8),
    w=st.integers(8, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_linreg_matches_ref(b, w, seed):
    r = rng(seed)
    slope = r.uniform(0.0, 0.3, size=(b, 1)).astype(np.float32)
    base = r.uniform(1.0, 8.0, size=(b, 1)).astype(np.float32)
    t = np.arange(w, dtype=np.float32)[None, :]
    req = base + slope * t + r.normal(0, 0.05, size=(b, w)).astype(np.float32)
    inv = 1.0 + 0.01 * t + r.normal(0, 0.01, size=(b, w)).astype(np.float32)
    n_valid = r.integers(3, w + 1, size=b).astype(np.float32)
    horizon = r.uniform(w, 4 * w, size=b).astype(np.float32)

    got = np.asarray(linreg_stats(req, inv, n_valid, horizon))
    want = np.asarray(ref.linreg_stats_ref(req, inv, n_valid, horizon))
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_linreg_exact_line_recovered():
    """A noiseless line must be recovered exactly: sigma ~ 0, pred on line."""
    w = 32
    t = np.arange(w, dtype=np.float32)
    req = (2.0 + 0.5 * t)[None, :]
    inv = np.ones((1, w), dtype=np.float32)
    stats = np.asarray(
        linreg_stats(req, inv, np.array([w], np.float32), np.array([100.0], np.float32))
    )[0]
    a_m, b_m, sigma_m = stats[0], stats[1], stats[2]
    assert abs(a_m - 0.5) < 1e-4
    assert abs(b_m - 2.0) < 1e-3
    assert sigma_m < 1e-3
    # mem_pred = 0.5*100 + 2 = 52 (+ z*~0)
    assert abs(stats[6] - 52.0) < 0.01
    # inv_reuse == 1 everywhere -> peak == mem_pred
    assert abs(stats[7] - stats[6]) < 0.05


def test_linreg_short_window_is_finite():
    """n_valid < 3 (degenerate fit) must not produce NaN/Inf."""
    b, w = 2, 16
    req = np.full((b, w), 5.0, np.float32)
    inv = np.ones((b, w), np.float32)
    out = np.asarray(
        linreg_stats(
            req, inv, np.array([1.0, 2.0], np.float32), np.array([50.0, 50.0], np.float32)
        )
    )
    assert np.isfinite(out).all()


# ------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    r_=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([16, 64, 128]),
    dh=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(r_, h, s, dh, seed):
    g = rng(seed)
    q = g.normal(size=(r_, h, dh)).astype(np.float32)
    k = g.normal(size=(r_, h, s, dh)).astype(np.float32)
    v = g.normal(size=(r_, h, s, dh)).astype(np.float32)
    lens = g.integers(1, s + 1, size=r_)
    bias = np.where(np.arange(s)[None, :] < lens[:, None], 0.0, -1e9).astype(np.float32)

    got = np.asarray(decode_attention(q, k, v, bias))
    want = np.asarray(ref.decode_attention_ref(q, k, v, bias))
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_attention_single_visible_position_returns_v():
    """With exactly one unmasked position, output must equal v there."""
    r_, h, s, dh = 2, 2, 8, 16
    g = rng(0)
    q = g.normal(size=(r_, h, dh)).astype(np.float32)
    k = g.normal(size=(r_, h, s, dh)).astype(np.float32)
    v = g.normal(size=(r_, h, s, dh)).astype(np.float32)
    bias = np.full((r_, s), -1e9, np.float32)
    bias[:, 3] = 0.0
    got = np.asarray(decode_attention(q, k, v, bias))
    assert_allclose(got, v[:, :, 3, :], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 4, 8, 128, 256]),
    k=st.sampled_from([16, 256]),
    n=st.sampled_from([8, 128, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    g = rng(seed)
    x = g.normal(size=(m, k)).astype(np.float32)
    w = g.normal(size=(k, n)).astype(np.float32)
    got = np.asarray(matmul(x, w))
    want = np.asarray(ref.matmul_ref(x, w))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    x = np.eye(128, dtype=np.float32)
    w = rng(1).normal(size=(128, 128)).astype(np.float32)
    assert_allclose(np.asarray(matmul(x, w)), w, rtol=1e-6, atol=1e-6)
