//! Online arrivals: the three scheduling policies over the same Poisson
//! job stream, driven by the event-driven orchestrator — the scenario
//! the batch experiments cannot express. Prints throughput, energy,
//! and the per-arrival queueing/turnaround percentiles side by side.
//!
//! ```sh
//! cargo run --release --example online_arrivals
//! ```

use migm::config::DEFAULT_SEED;
use migm::report;

fn main() {
    let rate_jps = 0.25; // one job every ~4s on average
    let (rows, table) = report::online_arrivals(DEFAULT_SEED, rate_jps);
    println!(
        "Ht2 mix over a Poisson arrival stream ({rate_jps} jobs/s, seed {DEFAULT_SEED}), \
         {} jobs:\n",
        rows[0].metrics.n_jobs
    );
    println!("{}", table.render());
    println!(
        "(queueing = arrival -> final launch; turnaround = arrival -> completion; \
         all policies run through the same Orchestrator event loop)"
    );

    // Side-by-side p99 turnaround, normalized to the baseline.
    let base = rows[0].latency.p99_turnaround_s;
    for r in &rows[1..] {
        println!(
            "{}: p99 turnaround {:.1}s vs baseline {:.1}s ({:.2}x better)",
            r.policy,
            r.latency.p99_turnaround_s,
            base,
            base / r.latency.p99_turnaround_s.max(1e-9)
        );
    }
}
