//! Online arrivals: the three scheduling policies over the same Poisson
//! job stream, driven by the event-driven orchestrator — the scenario
//! the batch experiments cannot express. Prints throughput, energy,
//! and the per-arrival queueing/turnaround percentiles side by side,
//! then drives the serving engine's diurnal smoke trace through the
//! same path `migm serve --smoke` uses (continuous batching +
//! SLO-driven autoscaling over one compressed synthetic day).
//!
//! ```sh
//! cargo run --release --example online_arrivals
//! ```

use migm::config::DEFAULT_SEED;
use migm::report;
use migm::serving::{self, ServeConfig};

fn main() {
    let rate_jps = 0.25; // one job every ~4s on average
    let (rows, table) = report::online_arrivals(DEFAULT_SEED, rate_jps);
    println!(
        "Ht2 mix over a Poisson arrival stream ({rate_jps} jobs/s, seed {DEFAULT_SEED}), \
         {} jobs:\n",
        rows[0].metrics.n_jobs
    );
    println!("{}", table.render());
    println!(
        "(queueing = arrival -> final launch; turnaround = arrival -> completion; \
         all policies run through the same Orchestrator event loop; the serving-auto \
         row is the serve engine's autoscaled smoke run)"
    );

    // Side-by-side p99 turnaround, normalized to the baseline. The
    // serving row measures a different workload, so skip it here.
    let base = rows[0].latency.p99_turnaround_s;
    for r in &rows[1..4] {
        println!(
            "{}: p99 turnaround {:.1}s vs baseline {:.1}s ({:.2}x better)",
            r.policy,
            r.latency.p99_turnaround_s,
            base,
            base / r.latency.p99_turnaround_s.max(1e-9)
        );
    }

    // The serving engine in full: the exact run behind `migm serve
    // --smoke` — one compressed diurnal day, one eco replica to start,
    // the autoscaler riding the wave up (promote, add) and back down
    // (drain, demote) — plus its scale-event log.
    println!("\nServing smoke run (migm serve --smoke, seed {DEFAULT_SEED}):\n");
    let sr = serving::run(&ServeConfig::smoke(DEFAULT_SEED));
    println!("{}", sr.render());
    for e in &sr.events {
        println!(
            "  t={:7.1}s  {:16}  -> {} replica(s)",
            e.t_s,
            e.action.label(),
            e.replicas_after
        );
    }
}
