//! A rack power budget over the A30/A100/H100 fleet: the
//! [`PowerGovernor`] holds a hard [`FleetPowerCap`] by deferring
//! admissions (never by letting the reserved draw breach), fissions
//! repeat offenders down to narrower profiles, parks drained GPUs at
//! 0 W, and — given a diurnal electricity price — shifts deferrable
//! work into the cheap window.
//!
//! Prints the E12 capped-vs-uncapped-vs-price-aware table, then a
//! direct governed run with its deferral timeline and the governor's
//! final counters. The cap-violation integral is asserted to be
//! exactly zero — the governor's contract, not a tuning outcome.
//!
//! Run: `cargo run --release --example power_cap`

use std::sync::Arc;

use migm::fleet::{FleetKnobs, FleetPolicy};
use migm::mig::GpuSpec;
use migm::power::{FleetPowerCap, PowerGovernor, PriceSignal};
use migm::report;
use migm::scheduler::{Orchestrator, SchemeBKnobs};
use migm::workloads::mix;

const SEED: u64 = 7;

fn main() {
    // ---- E12: the three-arm comparison over a shared price trace ---
    let (arms, table) = report::power_cap(SEED);
    println!("capped vs uncapped vs price-aware (Ht2, shared price trace):");
    println!("{}", table.render());
    for a in &arms[1..] {
        assert!(a.violation_s == 0.0, "{}: cap must hold exactly", a.label);
    }

    // ---- a direct governed run, with the deferral timeline ---------
    // Rack budget: every idle floor plus ~55% of the combined dynamic
    // range — one GPU fits easily, the fleet flat-out does not.
    let specs = vec![
        Arc::new(GpuSpec::a30_24gb()),
        Arc::new(GpuSpec::a100_40gb()),
        Arc::new(GpuSpec::h100_80gb()),
    ];
    let idle: f64 = specs.iter().map(|s| s.idle_power_w).sum();
    let range: f64 = specs.iter().map(|s| s.max_power_w - s.idle_power_w).sum();
    let cap_w = idle + 0.55 * range;

    // Diurnal tariff: $0.08/kWh in the trough, $0.42/kWh at the peak,
    // one "day" compressed to 600 s so the batch spans several cycles.
    let sig = PriceSignal::diurnal(0.08, 0.42, 600.0);
    let cap = FleetPowerCap::new(cap_w).with_price_deferral(0.15);
    let gov = PowerGovernor::new(cap).with_price(sig.clone());

    let policy = FleetPolicy::scheme_b(&specs, FleetKnobs::balanced(), SchemeBKnobs::default());
    let mut orch = Orchestrator::new(specs, false, policy);
    orch.set_power_governor(Some(gov));
    orch.set_price_signal(Some(sig));
    orch.submit_mix(&mix::ht2(SEED));
    orch.run_to_completion();

    let r = orch.fleet_result();
    let cost = orch.fleet_cost_usd();
    let g = orch.power_governor().expect("governor installed");

    println!(
        "governed run under {cap_w:.0} W rack cap (diurnal $0.08..$0.42/kWh):\n\
         completed {} jobs in {:.1}s — {:.0} J/job, ${:.4}/job",
        r.metrics.n_jobs,
        r.metrics.makespan_s,
        r.metrics.energy_per_job_j,
        cost / r.metrics.n_jobs.max(1) as f64
    );
    println!(
        "governor: {} cap deferrals, {} price deferrals, {} fissions, \
         {:.0} gpu-s parked; peak reserved {:.0} W, violations {:.1}s",
        g.deferrals(),
        g.price_deferrals(),
        g.fissions(),
        g.parked_gpu_s(),
        g.peak_reserved_w(),
        g.violation_s()
    );
    assert!(g.violation_s() == 0.0, "cap must hold exactly");
    assert!(g.peak_reserved_w() <= cap_w + 1e-9, "reserved draw stays under the cap");

    let tl = g.timeline();
    let shown = tl.len().min(12);
    println!("deferral timeline (first {shown} of {}):", tl.len());
    for ev in &tl[..shown] {
        println!(
            "  t={:7.1}s  {:5}  {}  (release t={:.1}s)",
            ev.t,
            ev.kind.as_str(),
            ev.job,
            ev.release_t
        );
    }
}
