//! Fault injection on a heterogeneous fleet: kill the A100 mid-run,
//! bring it back, and watch the recovery machinery — the scripted
//! [`FaultPlan`] drives the orchestrator's kill/restore seams, the
//! dead shard's queued jobs are re-queued through the fleet-steal
//! path, running jobs restart per the paper's recovery scheme, and
//! every submitted job still completes exactly once.
//!
//! Prints the recovery timeline, the re-queue/steal counters, the
//! final fleet metrics, and the `migm.bench.fault.v1` recovery row.
//!
//! Run: `cargo run --release --example fault_injection`

use std::sync::Arc;

use migm::fleet::{FleetKnobs, FleetPolicy};
use migm::mig::GpuSpec;
use migm::scheduler::{fault_recovery_row, run_with_faults, FaultPlan, Orchestrator, SchemeBKnobs};
use migm::workloads::rodinia;

fn main() {
    // A30 (gpu 0) + A100 (gpu 1) + H100 (gpu 2) — the mixed fleet from
    // the fleet-scheduler bench. GPU 1 is the one we kill.
    let specs = vec![
        Arc::new(GpuSpec::a30_24gb()),
        Arc::new(GpuSpec::a100_40gb()),
        Arc::new(GpuSpec::h100_80gb()),
    ];
    let names = ["A30", "A100", "H100"];
    let policy = FleetPolicy::scheme_b(&specs, FleetKnobs::balanced(), SchemeBKnobs::default());
    let mut orch = Orchestrator::new(specs, true, policy);

    // Staggered long/short pairs so the A100 holds both queued and
    // running work when the fault lands.
    let long = rodinia::by_name("euler3d").unwrap().job(7);
    let short = rodinia::by_name("bfs").unwrap().job(7);
    let n_pairs = 10;
    for i in 0..n_pairs {
        orch.submit_at(long.clone(), i as f64 * 0.8);
        orch.submit_at(short.clone(), i as f64 * 0.8 + 0.4);
    }

    let (kill_at, restore_at) = (6.0, 30.0);
    let plan = FaultPlan::kill_restore(1, kill_at, restore_at);
    let report = run_with_faults(&mut orch, &plan);

    println!("recovery timeline:");
    for row in &report.timeline {
        println!(
            "  t={:6.1}s  {:7}  gpu {} ({})  running jobs lost: {}",
            row.at_s,
            row.kind.as_str(),
            row.gpu,
            names[row.gpu],
            row.lost_running
        );
    }

    let steals = orch.policy().steals();
    let m = &report.result.metrics;
    println!(
        "re-queued {} running jobs; fleet stole {} jobs across shards",
        report.requeued_jobs, steals
    );
    println!(
        "completed {}/{} jobs: makespan {:.1}s, {:.0}J, p99 turnaround {:.1}s",
        report.result.records.len(),
        n_pairs * 2,
        m.makespan_s,
        m.energy_j,
        report.result.latency.p99_turnaround_s
    );
    assert_eq!(
        report.result.records.len(),
        n_pairs * 2,
        "every job completes exactly once"
    );
    assert!(!orch.is_down(1), "the A100 is back in service");

    let row = fault_recovery_row("fault_injection_example", &report, steals);
    println!("{row}");
}
