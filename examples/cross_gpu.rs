//! Extension beyond the paper's A100-only evaluation: the same
//! heterogeneous batch scheduled across every supported GPU model
//! (A30-24GB, A100-40GB, A100-80GB, H100-80GB). The partition FSM,
//! reachability table and both schemes are geometry-generic; this
//! example shows the improvement factors as the slice ladder changes.
//!
//! ```sh
//! cargo run --release --example cross_gpu [seed]
//! ```

use std::sync::Arc;

use migm::config::DEFAULT_SEED;
use migm::metrics::{fx, Table};
use migm::mig::{GpuSpec, ReachabilityTable};
use migm::scheduler::{baseline, scheme_a, scheme_b};
use migm::workloads::mix;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    let mut t = Table::new(&[
        "gpu",
        "full-configs",
        "batch",
        "A thr",
        "A energy",
        "B thr",
        "B energy",
    ]);
    for gpu in ["a30", "a100", "a100-80gb", "h100"] {
        let spec = Arc::new(GpuSpec::by_name(gpu).unwrap());
        // A30 can't hold the 25GB "full" Rodinia jobs; use the batch
        // that fits each GPU.
        let m = if spec.total_mem_gb < 40.0 {
            mix::preliminary_a30(seed)
        } else {
            mix::ht3(seed)
        };
        let table = ReachabilityTable::shared(&spec);
        let base = baseline::run(spec.clone(), &m);
        let a = scheme_a::run(spec.clone(), &m, false);
        let b = scheme_b::run(spec.clone(), &m, false);
        let na = a.metrics.normalized_vs(&base.metrics);
        let nb = b.metrics.normalized_vs(&base.metrics);
        t.row(vec![
            spec.name.clone(),
            format!("{}", table.full_config_count()),
            format!("{} jobs ({})", m.jobs.len(), m.name),
            fx(na.throughput),
            fx(na.energy),
            fx(nb.throughput),
            fx(nb.energy),
        ]);
    }
    println!("== MIGM across GPU models (seed {seed}) ==\n");
    println!("{}", t.render());
    println!(
        "(80GB models fit the same mixes on tighter relative slices; the\n\
         partition FSM adapts automatically — no per-GPU code)"
    );
}
