//! End-to-end serving driver: the full three-layer stack on a real
//! workload.
//!
//! Loads the AOT decode artifact (L1 Pallas kernels inside an L2 JAX
//! graph, compiled to HLO), partitions the A100 model into MIG replica
//! slices, starts the rust serving system (router + continuous slot
//! batcher + PJRT execution), drives a batch of generation requests
//! through it, and reports throughput and latency percentiles. The AOT
//! Pallas *predictor* artifact watches each replica's KV growth — the
//! paper's early-resize signal on the live path.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! cargo run --release --example llm_serving
//! ```

use std::sync::Arc;
use std::time::Instant;

use migm::server::{GenRequest, ServingConfig, ServingSystem};
use migm::util::Rng;

fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((p * (sorted.len() - 1) as f64) as usize).min(sorted.len() - 1)]
}

fn main() -> anyhow::Result<()> {
    let cfg = ServingConfig {
        replicas: 2,
        ..Default::default()
    };
    println!("starting serving system: variant={} replicas={}", cfg.variant, cfg.replicas);
    let sys = Arc::new(ServingSystem::start(cfg)?);
    println!("replica slices (partition-manager placements): {:?}\n", sys.replica_slices);

    // A realistic request sweep: varying prompt lengths and budgets.
    let n_requests = 24;
    let mut rng = Rng::new(11);
    let reqs: Vec<GenRequest> = (0..n_requests)
        .map(|_| {
            let plen = rng.range(1, 12);
            GenRequest {
                prompt: (0..plen).map(|_| rng.range(1, 500) as i32).collect(),
                max_new: rng.range(8, 32),
            }
        })
        .collect();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for r in reqs {
        let sys = sys.clone();
        handles.push(std::thread::spawn(move || sys.generate(r)));
    }
    let mut latencies: Vec<f64> = Vec::new();
    let mut total_tokens = 0usize;
    let mut per_replica = [0usize; 8];
    for h in handles {
        let r = h.join().expect("client thread")?;
        latencies.push(r.latency_ms);
        total_tokens += r.tokens.len();
        per_replica[r.replica.min(7)] += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let stats = sys.stats()?;
    println!("served {n_requests} requests, {total_tokens} generated tokens in {wall:.2}s");
    println!(
        "throughput: {:.1} tok/s ({:.1} req/s)   decode steps: {}",
        total_tokens as f64 / wall,
        n_requests as f64 / wall,
        stats.decode_steps
    );
    println!(
        "latency  p50 {:.1} ms   p95 {:.1} ms   p99 {:.1} ms   max {:.1} ms",
        pct(&latencies, 0.50),
        pct(&latencies, 0.95),
        pct(&latencies, 0.99),
        latencies.last().unwrap()
    );
    println!(
        "router balance: {:?}   kv-growth alerts from the Pallas predictor: {}",
        &per_replica[..2],
        stats.kv_alerts
    );
    Ok(())
}
