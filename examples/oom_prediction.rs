//! The OOM-prediction case study (paper §2.3 / §5.2.2): for each dynamic
//! workload, show when the predictor converges vs when the OOM would
//! actually strike, and the predictor's accuracy at 10% of iterations.
//! Also traces the Qwen2 run iteration by iteration, like the paper's
//! motivating example.
//!
//! ```sh
//! cargo run --release --example oom_prediction [seed]
//! ```

use migm::config::DEFAULT_SEED;
use migm::predictor::{ConvergenceCfg, JobMonitor, PredictionOutcome};
use migm::report;
use migm::workloads::llm;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);

    println!("== E7/E8: OOM prediction across all dynamic workloads ==\n");
    let (rows, t) = report::oom_case_study(seed);
    println!("{}", t.render());
    let avg_err =
        rows.iter().map(|r| r.err_at_10pct).sum::<f64>() / rows.len() as f64 * 100.0;
    println!("average prediction error at 10% of iterations: {avg_err:.2}% (paper: 14.98%)\n");

    // ---- Qwen2 motivating example, iteration by iteration ----
    println!("== Qwen2-7B on a 10GB slice (paper §2.3) ==\n");
    let w = llm::qwen2_7b();
    let trace = w.trace.generate(seed);
    let cap = 10.0;
    let mut mon = JobMonitor::new(w.trace.n_iters, ConvergenceCfg::default());
    let mut predicted_at = None;
    for i in 0..trace.len() {
        let phys = trace.phys_gb[i];
        if let PredictionOutcome::Converged { peak_physical_gb } = mon.push(trace.observation(i)) {
            if predicted_at.is_none() && peak_physical_gb > cap {
                predicted_at = Some(i);
                println!(
                    "iter {i:>3}: phys {phys:5.2} GB — predictor CONVERGED: \
                     projected peak {peak_physical_gb:.2} GB > {cap} GB slice -> early restart"
                );
            }
        }
        if phys > cap {
            println!("iter {i:>3}: phys {phys:5.2} GB — OOM would strike here");
            let saved = i - predicted_at.unwrap_or(0);
            println!(
                "\nearly restart saves {saved} wasted iterations \
                 (paper: predicted at 6, OOM at 94)"
            );
            break;
        }
        if i < 10 || i % 20 == 0 {
            println!("iter {i:>3}: phys {phys:5.2} GB");
        }
    }
}
