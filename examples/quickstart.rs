//! Quickstart: run one heterogeneous Rodinia batch under every
//! scheduling policy and compare against the sequential baseline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use migm::config::DEFAULT_SEED;
use migm::metrics::{fx, Table};
use migm::mig::GpuSpec;
use migm::scheduler::{baseline, scheme_a, scheme_b};
use migm::workloads::mix;

fn main() {
    let spec = Arc::new(GpuSpec::a100_40gb());
    let m = mix::ht3(DEFAULT_SEED);
    println!(
        "mix {} — {} jobs on {} ({} GPCs, {} GB)\n",
        m.name,
        m.jobs.len(),
        spec.name,
        spec.total_compute,
        spec.total_mem_gb
    );

    let base = baseline::run(spec.clone(), &m);
    let a = scheme_a::run(spec.clone(), &m, false);
    let b = scheme_b::run(spec.clone(), &m, false);

    let mut t = Table::new(&[
        "policy",
        "makespan (s)",
        "throughput",
        "energy",
        "mem-util",
        "turnaround",
        "reconfigs",
    ]);
    t.row(vec![
        "baseline (sequential)".into(),
        format!("{:.1}", base.metrics.makespan_s),
        "1.00x".into(),
        "1.00x".into(),
        "1.00x".into(),
        "1.00x".into(),
        "0".into(),
    ]);
    for (name, r) in [("scheme A (by size)", &a), ("scheme B (FIFO)", &b)] {
        let n = r.metrics.normalized_vs(&base.metrics);
        t.row(vec![
            name.into(),
            format!("{:.1}", r.metrics.makespan_s),
            fx(n.throughput),
            fx(n.energy),
            fx(n.mem_utilization),
            fx(n.turnaround),
            format!("{}", r.metrics.reconfig_ops),
        ]);
    }
    println!("{}", t.render());
    println!("(normalized factors: >1.00x means better than the baseline)");
}
