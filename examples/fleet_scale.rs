//! Fleet-scale what-if: 1024 jobs in flight across 64 synthetic
//! 16-instance GPUs through the indexed DES engine — the scenario
//! class the scan-and-decrement loop made impractical (four O(n)
//! scans plus a clone per event, per engine). Prints simulated
//! makespan and the wall-clock processing rate — the knob that bounds
//! how many MIG-configuration what-ifs a policy-search loop can
//! evaluate.
//!
//! The GPU model and job come from [`migm::workloads::synthetic`], the
//! exact scenario `benches/des_engine.rs` measures.
//!
//! Run: `cargo run --release --example fleet_scale`

use std::sync::Arc;
use std::time::Instant;

use migm::sim::{GpuSim, SimEvent};
use migm::workloads::synthetic::{fleet_job, many_instance_spec};

fn main() {
    let spec = Arc::new(many_instance_spec(16));
    let job = fleet_job(100);

    let (n_gpus, per_gpu) = (64, 16);
    let t0 = Instant::now();
    let mut finished = 0usize;
    let mut makespan: f64 = 0.0;
    let mut energy = 0.0;
    for _ in 0..n_gpus {
        let mut sim = GpuSim::new(spec.clone(), false);
        for _ in 0..per_gpu {
            let inst = sim.mgr.alloc(0).unwrap();
            sim.launch(job.clone(), inst, 0.0);
        }
        while let Some(ev) = sim.advance() {
            if matches!(ev, SimEvent::Finished { .. }) {
                finished += 1;
            }
        }
        makespan = makespan.max(sim.now());
        energy += sim.energy_j();
    }
    let wall = t0.elapsed();
    println!(
        "fleet: {} GPUs x {} jobs = {} in flight",
        n_gpus,
        per_gpu,
        n_gpus * per_gpu
    );
    println!("completed {finished} jobs, makespan {makespan:.2}s simulated, {energy:.0}J");
    println!(
        "wall {:.1}ms -> {:.1}k simulated job-seconds per wall-second",
        wall.as_secs_f64() * 1e3,
        finished as f64 * makespan / wall.as_secs_f64() / 1e3
    );
}
