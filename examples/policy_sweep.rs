//! Policy-search sweep over scheduler knobs on simulated fleets.
//!
//! Demonstrates the `tuner` subsystem end to end: build a typed
//! `ParamSpace` over Scheme A/B knobs, prune it with successive
//! halving on short horizons, re-score the survivors on full fleets
//! (paper Ht2 on an A100 plus a 2-GPU tiered synthetic fleet), and
//! print the ranked, reproducible report — the same path as
//! `migm tune`, whose JSON artifact feeds the CI perf trajectory.
//!
//! Run with: `cargo run --example policy_sweep`

use migm::config::DEFAULT_SEED;
use migm::tuner::{sweep, Generator, ParamSpace, Scenario, SweepConfig};

fn main() {
    let seed = DEFAULT_SEED;
    let cfg = SweepConfig {
        space: ParamSpace::smoke(),
        scenarios: vec![
            Scenario::synthetic_fleet(2, seed),
            Scenario::paper("ht2", seed).expect("known mix"),
        ],
        generator: Generator::Halving {
            n: 0, // prune the full grid
            eta: 2,
            finalists: 3,
            short_frac: 0.3,
        },
        seed,
        threads: std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    };
    let report = sweep(&cfg).expect("sweep");
    println!("{}", report.render());

    let best = report.best();
    println!(
        "winner: {}  (objective {:.4}, reference = 1.0)",
        best.candidate.label(),
        best.objective
    );
    println!("winning candidate JSON: {}", best.candidate.to_json());
    println!(
        "sweep trajectory rounds: {} (last = full horizon)",
        report.trajectory.len()
    );
}
