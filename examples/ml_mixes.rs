//! Figures 4e–4h: the DNN mixes (Ml1–Ml3) and the dynamic LLM
//! workloads, with and without time-series prediction.
//!
//! ```sh
//! cargo run --release --example ml_mixes [seed]
//! ```

use migm::config::DEFAULT_SEED;
use migm::report;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);

    println!("== Figures 4e-4h (DNN): Ml1-Ml3 (seed {seed}) ==\n");
    let (ml_rows, t) = report::fig4_ml(seed);
    println!("{}", t.render());

    // Paper §5.2.1 corner case: Ml3 is the one mix where B beats A
    // (static split over the asymmetric 4g/3g pair idles the fast half).
    let a3 = ml_rows.iter().find(|r| r.mix == "Ml3" && r.scheme == "A").unwrap();
    let b3 = ml_rows.iter().find(|r| r.mix == "Ml3" && r.scheme == "B").unwrap();
    println!(
        "Ml3 corner case: A {:.2}x vs B {:.2}x (paper: A 1.24x, B 1.43x — B wins)\n",
        a3.norm.throughput, b3.norm.throughput
    );

    println!("== Figures 4e-4h (dynamic): LLM workloads ==\n");
    let (llm_rows, t) = report::fig4_llm(seed);
    println!("{}", t.render());

    let avg = |label: &str| {
        let rs: Vec<_> = llm_rows.iter().filter(|r| r.scheme == label).collect();
        let thr = rs.iter().map(|r| r.norm.throughput).sum::<f64>() / rs.len() as f64;
        let en = rs.iter().map(|r| r.norm.energy).sum::<f64>() / rs.len() as f64;
        let ut = rs.iter().map(|r| r.norm.mem_utilization).sum::<f64>() / rs.len() as f64;
        (thr, en, ut)
    };
    let (thr, en, ut) = avg("A+pred");
    println!(
        "A+prediction averages: throughput {:.1}% energy {:.1}% mem-util {:.1}% \
         (paper: +25.13% / +6.96% / +20.73%)",
        (thr - 1.0) * 100.0,
        (en - 1.0) * 100.0,
        (ut - 1.0) * 100.0
    );
}
