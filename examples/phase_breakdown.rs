//! Tables 3 and 4: per-phase overheads of MIG slicing.
//!
//! * Table 3 — myocyte phase breakdown: allocator bookkeeping grows with
//!   the number of live MIG instances.
//! * Table 4 — Needleman-Wunsch: PCIe bandwidth contention stretches the
//!   transfer-bound benchmark when 7 copies run concurrently.
//!
//! ```sh
//! cargo run --release --example phase_breakdown
//! ```

use migm::report;

fn main() {
    println!("== Table 3: myocyte run breakdown, Scheme A vs baseline ==\n");
    let (_, t3) = report::table3_myocyte();
    println!("{}", t3.render());

    println!("== Table 4: Needleman-Wunsch under PCIe contention ==\n");
    let (r, t4) = report::table4_nw();
    println!("{}", t4.render());
    println!(
        "individual slowdown: {:.2}x (paper: 1171507us / 523406us = 2.24x)\n\
         batch-21 throughput: {:.2}x of baseline (paper: 1.92x vs 7x ceiling)",
        r.contended_runtime_s / r.solo_runtime_s,
        r.batch21_throughput_x
    );
}
