//! Figures 4a–4d: all seven Rodinia mixes (Table 1) under Scheme A and
//! Scheme B, normalized to the sequential full-GPU baseline.
//!
//! ```sh
//! cargo run --release --example rodinia_mixes [seed]
//! ```

use migm::config::DEFAULT_SEED;
use migm::report;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    println!("== Figures 4a-4d: Rodinia mixes (seed {seed}) ==\n");
    let (rows, table) = report::fig4_rodinia(seed);
    println!("{}", table.render());
    // Headline checks from the paper's §5.1.
    let best = rows
        .iter()
        .max_by(|a, b| a.norm.throughput.partial_cmp(&b.norm.throughput).unwrap())
        .unwrap();
    println!(
        "best throughput: {} under scheme {} at {:.2}x (paper: up to 6.20x)",
        best.mix, best.scheme, best.norm.throughput
    );
    let best_e = rows
        .iter()
        .max_by(|a, b| a.norm.energy.partial_cmp(&b.norm.energy).unwrap())
        .unwrap();
    println!(
        "best energy saving: {} under scheme {} at {:.2}x (paper: up to 5.93x)",
        best_e.mix, best_e.scheme, best_e.norm.energy
    );
}
