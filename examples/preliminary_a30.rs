//! The §1 preliminary experiment: 14 random Rodinia jobs on an A30,
//! tightest-fit slices vs next-largest slices (paper: +20.6% throughput,
//! +6.3% energy for tight fits).
//!
//! ```sh
//! cargo run --release --example preliminary_a30 [seed]
//! ```

use migm::config::DEFAULT_SEED;
use migm::report;

fn main() {
    let seed = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_SEED);
    println!("== §1 preliminary experiment on the A30 (seed {seed}) ==\n");
    let (r, t) = report::preliminary_a30(seed);
    println!("{}", t.render());
    println!(
        "tightest-fit improvement: throughput +{:.1}% (paper +20.6%), \
         energy +{:.1}% (paper +6.3%)",
        (r.throughput_gain - 1.0) * 100.0,
        (r.energy_gain - 1.0) * 100.0
    );
}
